"""Layer-2 model assembly: target VLMs and MASSV drafters.

A target VLM is ``M_p^VLM = (phi_I^p, g_theta^p, M_p)`` (Section 2.2); a
MASSV drafter is ``M_q^VLM = (phi_I^p, g_psi^q, M_q)`` (Eq. 1) -- it REUSES
the target's frozen vision encoder and owns a fresh projector sized to the
SLM's embedding width (Eq. 2).

This module defines the inference entry points that aot.py lowers to HLO
text (with weights baked as constants) for the Rust runtime:

  prefill_mm     image + prompt -> last-position logits + KV
  prefill_text   prompt only    -> last-position logits + KV
  verify         gamma+1 tokens @ pos -> logits for each + KV   (target)
  decode         1 token @ pos -> logits + KV     (non-speculative baseline)
  draft_scan     fused on-device draft loop: gamma tokens sampled by
                 gumbel-max at temperature T (T=0 degenerates to argmax),
                 returning the raw q-logits the coordinator needs for
                 stochastic acceptance (Section 2.1).

Sequence layout (multimodal): [visual 0..m-1][text m..m+P_max-1][generation]
Generation starts at absolute position m + prompt_len.  Text-only models
drop the visual prefix.  The KV cache is a packed [L, 2, H, T_max, Dh]
array; stale tail entries are masked by position (see kernels/ref.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import nn
from .config import GAMMA, ModelConfig

# ---------------------------------------------------------------------------
# Parameter bundles
# ---------------------------------------------------------------------------


def init_target_params(cfg: ModelConfig, seed: int) -> dict:
    return {
        "vision": nn.init_vision_params(cfg.vision, seed + 1),
        "proj": nn.init_projector_params(cfg.vision.d_vis, cfg.d_model, seed + 2),
        "lm": nn.init_lm_params(cfg, seed + 3),
    }


def init_drafter_params(cfg: ModelConfig, target_vision: dict, lm: dict, seed: int) -> dict:
    """Architectural adaptation (Section 3.1): graft the target's vision
    encoder, add a randomly initialized projector, keep the SLM backbone."""
    return {
        "vision": target_vision,  # shared, frozen
        "proj": nn.init_projector_params(cfg.vision.d_vis, cfg.d_model, seed),
        "lm": lm,
    }


# ---------------------------------------------------------------------------
# Embedding assembly
# ---------------------------------------------------------------------------


def visual_embeds(params: dict, cfg: ModelConfig, image: jnp.ndarray) -> jnp.ndarray:
    feats = nn.vision_encode(params["vision"], cfg.vision, image)
    return nn.project_visual(params["proj"], feats)  # [m, d]


def token_embeds(params: dict, ids: jnp.ndarray) -> jnp.ndarray:
    return params["lm"]["embed"][ids]


# ---------------------------------------------------------------------------
# Inference entry points (lowered by aot.py; batch = 1)
# ---------------------------------------------------------------------------


def prefill_mm(
    params: dict,
    cfg: ModelConfig,
    image: jnp.ndarray,  # [16, 16, 3] f32
    prompt_ids: jnp.ndarray,  # [P_max] i32 (padded with <pad>)
    prompt_len,  # scalar i32
    *,
    use_kernel: bool = True,
):
    """Multimodal prefill.  Returns (last_logits [V], kv)."""
    vis = visual_embeds(params, cfg, image)
    tok = token_embeds(params, prompt_ids)
    embeds = jnp.concatenate([vis, tok], axis=0)  # [m + P_max, d]
    kv = nn.empty_kv(cfg)
    logits, kv = nn.lm_forward_cached(
        params["lm"], cfg, embeds, kv, 0, use_kernel=use_kernel
    )
    last = logits[cfg.n_visual + prompt_len - 1]
    return last, kv


def prefill_text(
    params: dict,
    cfg: ModelConfig,
    prompt_ids: jnp.ndarray,  # [P_max] i32
    prompt_len,
    *,
    use_kernel: bool = True,
):
    """Text-only prefill (baseline drafting / Table-3 text-only mode)."""
    tok = token_embeds(params, prompt_ids)
    kv = nn.empty_kv(cfg)
    logits, kv = nn.lm_forward_cached(
        params["lm"], cfg, tok, kv, 0, use_kernel=use_kernel
    )
    last = logits[prompt_len - 1]
    return last, kv


def extend(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # [S] i32
    pos,  # scalar i32
    kv: jnp.ndarray,
    *,
    use_kernel: bool = True,
):
    """Process S tokens starting at absolute position pos.

    S = gamma+1 -> target verify; S = 1 -> single decode step."""
    embeds = token_embeds(params, tokens)
    logits, kv = nn.lm_forward_cached(
        params["lm"], cfg, embeds, kv, pos, use_kernel=use_kernel
    )
    return logits, kv


def draft_scan(
    params: dict,
    cfg: ModelConfig,
    last_token,  # scalar i32: last accepted token
    pos,  # scalar i32: its write position + 1 == first draft position
    kv: jnp.ndarray,
    temperature,  # scalar f32 (0 -> greedy)
    seed,  # scalar u32 (gumbel-max sampling noise)
    *,
    gamma: int = GAMMA,
    use_kernel: bool = True,
):
    """Fused on-device draft loop (the key L2/L3 co-design optimization:
    one PJRT call drafts all gamma tokens instead of gamma round-trips).

    Gumbel-max sampling draws token ~ softmax(logits / T) exactly, so the
    coordinator's acceptance test (which recomputes q = softmax(logits / T)
    host-side from the returned raw logits) sees a consistent (token, q)
    pair -- required for the losslessness guarantee of Section 2.1.

    Returns (tokens [gamma] i32, q_logits [gamma, V] f32, kv')."""
    key0 = jax.random.PRNGKey(seed)
    temperature = jnp.asarray(temperature, jnp.float32)

    def step(carry, _):
        tok, p, kv, key = carry
        emb = token_embeds(params, tok[None])  # [1, d]
        logits, kv = nn.lm_forward_cached(
            params["lm"], cfg, emb, kv, p, use_kernel=use_kernel
        )
        lg = logits[0]  # [V] raw logits
        key, sub = jax.random.split(key)
        u = jax.random.uniform(sub, lg.shape, minval=1e-7, maxval=1.0 - 1e-7)
        gumbel = -jnp.log(-jnp.log(u))
        t_eff = jnp.maximum(temperature, 1e-4)
        noisy = lg / t_eff + gumbel * (temperature > 0).astype(jnp.float32)
        ntok = jnp.argmax(noisy).astype(jnp.int32)
        return (ntok, p + 1, kv, key), (ntok, lg)

    (_, _, kv, _), (tokens, qlogits) = jax.lax.scan(
        step, (jnp.asarray(last_token, jnp.int32), pos, kv, key0), None, length=gamma
    )
    return tokens, qlogits, kv


# ---------------------------------------------------------------------------
# Training forwards (batched, full sequence)
# ---------------------------------------------------------------------------


def train_logits_mm(
    params: dict,
    cfg: ModelConfig,
    images: jnp.ndarray,  # [B, 16, 16, 3]
    tokens: jnp.ndarray,  # [B, S_txt] i32
) -> jnp.ndarray:
    """Batched multimodal forward: [visual m][text S_txt].  Returns logits
    aligned to text positions: [B, S_txt, V] where logits[:, i] predicts
    tokens[:, i+1]."""
    vis = jax.vmap(lambda im: visual_embeds(params, cfg, im))(images)
    tok = params["lm"]["embed"][tokens]
    embeds = jnp.concatenate([vis, tok], axis=1)
    logits = nn.lm_forward_train(params["lm"], cfg, embeds)
    return logits[:, cfg.n_visual :, :]


def train_logits_text(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    tok = params["lm"]["embed"][tokens]
    return nn.lm_forward_train(params["lm"], cfg, tok)


def next_token_loss(logits: jnp.ndarray, tokens: jnp.ndarray, mask: jnp.ndarray):
    """Cross-entropy of logits[:, :-1] predicting tokens[:, 1:], weighted by
    mask[:, 1:] (1.0 on supervised positions).  Eq. 3 / Eq. 5 shape."""
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
