"""Self-data distillation: sample responses from the target VLM (Eq. 4).

``y'_i = sample_top-p(p(. | I_i, X_i))`` -- the target generates its own
training labels for the drafter.  Per the paper, diversity matters (it
prevents "teacher hacking"): we sample at several temperatures with top-p
nucleus filtering and emit one distilled example per (prompt, temperature).

Generation is batched and jitted (pure-jnp attention path: the Pallas
kernel is reserved for the AOT inference artifacts; equality of the two
paths is asserted by python/tests/test_model.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model, shapeworld
from .config import GEN_MAX, P_MAX, ModelConfig


def _pad_prompt(prompt_ids: list[int]) -> tuple[np.ndarray, int]:
    ids = [shapeworld.BOS_ID] + prompt_ids + [shapeworld.SEP_ID]
    if len(ids) > P_MAX:
        raise ValueError(f"prompt too long: {len(ids)}")
    out = np.full(P_MAX, shapeworld.PAD_ID, dtype=np.int32)
    out[: len(ids)] = ids
    return out, len(ids)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _batched_prefill(params, cfg: ModelConfig, images, prompts, lens):
    return jax.vmap(
        lambda im, pr, ln: model.prefill_mm(params, cfg, im, pr, ln, use_kernel=False)
    )(images, prompts, lens)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _batched_step(params, cfg: ModelConfig, tokens, positions, kv):
    return jax.vmap(
        lambda t, p, c: model.extend(params, cfg, t[None], p, c, use_kernel=False)
    )(tokens, positions, kv)


def _top_p_sample(
    logits: np.ndarray, temperature: float, top_p: float, rng: np.random.Generator
) -> int:
    """Nucleus sampling on the host (matches rust/src/spec/sampler.rs)."""
    if temperature <= 0.0:
        return int(np.argmax(logits))
    z = logits / temperature
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    order = np.argsort(-p)
    csum = np.cumsum(p[order])
    cut = int(np.searchsorted(csum, top_p) + 1)
    keep = order[:cut]
    pk = p[keep] / p[keep].sum()
    return int(rng.choice(keep, p=pk))


def generate_batch(
    params: dict,
    cfg: ModelConfig,
    examples: list[shapeworld.Example],
    temperature: float,
    top_p: float,
    rng: np.random.Generator,
    max_new: int = GEN_MAX - 1,
) -> list[list[int]]:
    """Greedy/top-p generation for a batch of multimodal prompts.
    Returns generated token id lists (without the trailing <eos>)."""
    b = len(examples)
    images = jnp.asarray(np.stack([e.image for e in examples]))
    padded = [_pad_prompt(e.prompt_ids) for e in examples]
    prompts = jnp.asarray(np.stack([p for p, _ in padded]))
    lens = jnp.asarray(np.array([l for _, l in padded], dtype=np.int32))

    last_logits, kv = _batched_prefill(params, cfg, images, prompts, lens)
    positions = np.array([cfg.n_visual + l for _, l in padded], dtype=np.int32)

    out: list[list[int]] = [[] for _ in range(b)]
    done = np.zeros(b, dtype=bool)
    logits_np = np.asarray(last_logits)

    for _ in range(max_new):
        toks = np.zeros(b, dtype=np.int32)
        for i in range(b):
            if done[i]:
                toks[i] = shapeworld.PAD_ID
                continue
            t = _top_p_sample(logits_np[i], temperature, top_p, rng)
            toks[i] = t
            if t == shapeworld.EOS_ID:
                done[i] = True
            else:
                out[i].append(t)
        if done.all():
            break
        step_logits, kv = _batched_step(
            params, cfg, jnp.asarray(toks), jnp.asarray(positions), kv
        )
        positions += 1
        logits_np = np.asarray(step_logits)[:, 0, :]
    return out


def load_acceptance_telemetry(path: str) -> list[dict]:
    """Load the serving engine's acceptance-telemetry JSONL export
    (``EngineConfig::calib_jsonl`` in rust/src/coordinator/engine.rs; one
    object per speculative iteration).

    Each record carries ``class`` (workload class tag), ``mode``
    ("chain" | "tree"), ``drafted``/``accepted`` token counts, and
    ``image_reuse`` (whether the request's prefill was served warm).  The
    self-distillation pipeline uses these to weight D' toward the
    workload classes where drafter agreement is weakest -- the serving
    feedback loop described in docs/drafting.md.  Malformed lines are
    skipped (the engine may still be appending when the file is read).
    """
    import json

    records: list[dict] = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if not {"class", "mode", "drafted", "accepted"} <= rec.keys():
                continue
            records.append(rec)
    return records


def acceptance_by_class(records: list[dict]) -> dict[str, float]:
    """Pooled per-token acceptance rate per workload class -- the
    quantity that decides which classes need more distillation data."""
    drafted: dict[str, int] = {}
    accepted: dict[str, int] = {}
    for r in records:
        c = r["class"]
        drafted[c] = drafted.get(c, 0) + int(r["drafted"])
        accepted[c] = accepted.get(c, 0) + int(r["accepted"])
    return {c: accepted[c] / drafted[c] for c in drafted if drafted[c] > 0}


def distill_dataset(
    target_params: dict,
    target_cfg: ModelConfig,
    dataset: list[shapeworld.Example],
    *,
    temperatures: tuple[float, ...],
    top_p: float,
    seed: int,
    batch_size: int = 64,
) -> list[shapeworld.Example]:
    """Create D' = {(I_i, X_i, y'_i)}: same images and instructions, labels
    replaced by target VLM samples (one pass per temperature)."""
    rng = np.random.default_rng(seed)
    distilled: list[shapeworld.Example] = []
    for temp in temperatures:
        for i in range(0, len(dataset), batch_size):
            chunk = dataset[i : i + batch_size]
            gens = generate_batch(target_params, target_cfg, chunk, temp, top_p, rng)
            for ex, ids in zip(chunk, gens):
                if not ids:  # degenerate sample; keep dataset label
                    ids = ex.answer_ids
                distilled.append(
                    shapeworld.Example(
                        image=ex.image,
                        prompt_ids=ex.prompt_ids,
                        answer_ids=ids,
                        task=ex.task,
                    )
                )
    return distilled
