"""Shape-world: the synthetic multimodal universe used for the reproduction.

The paper trains/evaluates on LLaVA-Pretrain-LCS-558K, LLaVA-mix-665K, GQA,
COCO and LLaVA-Bench.  None of those are available offline, so we substitute
a procedurally generated world that preserves the property MASSV exploits:
*visually grounded tokens (colors, shapes, positions) are unpredictable from
text alone, while function words are predictable*.

Images are 16x16x3 float32 arrays holding a 2x2 grid of colored shape glyphs.
Captions and QA pairs come from a compositional grammar with multiple
equivalent phrasings, so a trained target VLM develops idiosyncratic
phrasing preferences that fixed-label fine-tuning cannot capture but
self-data distillation (SDViT) can -- the mechanism under test.

Everything is deterministic given a seed.  The same vocabulary is exported
to artifacts/vocab.json and re-implemented byte-for-byte by the Rust
tokenizer (rust/src/tokenizer/).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

# ---------------------------------------------------------------------------
# Vocabulary
# ---------------------------------------------------------------------------

PAD, BOS, EOS, SEP, IMG = "<pad>", "<bos>", "<eos>", "<sep>", "<img>"
SPECIALS = [PAD, BOS, EOS, SEP, IMG]

COLORS = ["red", "blue", "green", "yellow", "purple", "orange"]
SHAPES = ["circle", "square", "triangle", "star", "cross", "heart"]
POSITIONS = ["top left", "top right", "bottom left", "bottom right"]
POSITION_WORDS = ["top", "bottom", "left", "right"]
NUMBER_WORDS = ["zero", "one", "two", "three", "four"]

_CORE_WORDS = [
    # articles / function words
    "the", "a", "an", "is", "are", "in", "on", "and", "of", "there",
    "image", "shows", "picture", "contains", "you", "can", "see",
    "corner", "it", "its", "this", "that", "with", "has", "empty",
    # question words
    "what", "which", "how", "many", "where", "color", "shape", "shapes",
    "describe", "briefly", "detail", "tell", "me", "about", "visible",
    "question", "answer", "reasoning", "because", "so", "first", "then",
    "look", "at", "region", "each", "total", "count", "found", "object",
    "objects", "located", "no", "yes", "nothing", "scene", "grid",
    "cell", "cells", "contain", "containing", "colored", "drawn",
    "explain", "your", "step", "by", "final", "i", "identify", "all",
    "therefore", "next", "other", "same", "different", "quadrant",
    "please", "list", "every", "detailed", "comprehensive", "provide",
    "description", "location",
    ".", ",", "?", ":",
]


def build_vocab() -> list[str]:
    """The canonical token list.  Index == token id."""
    words: list[str] = []
    words.extend(SPECIALS)
    words.extend(COLORS)
    words.extend(SHAPES)
    words.extend(POSITION_WORDS)
    words.extend(NUMBER_WORDS)
    for w in _CORE_WORDS:
        if w not in words:
            words.append(w)
    return words


VOCAB = build_vocab()
TOK2ID = {w: i for i, w in enumerate(VOCAB)}
VOCAB_SIZE = len(VOCAB)
PAD_ID, BOS_ID, EOS_ID, SEP_ID, IMG_ID = (TOK2ID[t] for t in SPECIALS)


def encode(text: str) -> list[int]:
    """Word-level encode.  Punctuation must be space-separated by callers;
    the grammar below always emits canonical spacing."""
    ids = []
    for w in text.split():
        if w not in TOK2ID:
            raise KeyError(f"OOV word {w!r} (grammar bug)")
        ids.append(TOK2ID[w])
    return ids


def decode(ids) -> str:
    return " ".join(VOCAB[int(i)] for i in ids)


# ---------------------------------------------------------------------------
# Images
# ---------------------------------------------------------------------------

IMG_SIZE = 16
CELL = 8  # 2x2 grid of 8x8 cells

# 8x8 binary glyphs, hand drawn; distinct under the 4x4 patching used by the
# vision encoder.
_GLYPHS = {
    "circle": [
        "..####..",
        ".#....#.",
        "#......#",
        "#......#",
        "#......#",
        "#......#",
        ".#....#.",
        "..####..",
    ],
    "square": [
        "########",
        "########",
        "##....##",
        "##....##",
        "##....##",
        "##....##",
        "########",
        "########",
    ],
    "triangle": [
        "...##...",
        "...##...",
        "..####..",
        "..####..",
        ".######.",
        ".######.",
        "########",
        "########",
    ],
    "star": [
        "...#....",
        "..###...",
        "#######.",
        ".#####..",
        "..###...",
        ".##.##..",
        "##...##.",
        "#.....#.",
    ],
    "cross": [
        "...##...",
        "...##...",
        "...##...",
        "########",
        "########",
        "...##...",
        "...##...",
        "...##...",
    ],
    "heart": [
        ".##..##.",
        "########",
        "########",
        "########",
        ".######.",
        "..####..",
        "...##...",
        "........",
    ],
}

_RGB = {
    "red": (1.0, 0.1, 0.1),
    "blue": (0.1, 0.2, 1.0),
    "green": (0.1, 0.9, 0.2),
    "yellow": (1.0, 0.9, 0.1),
    "purple": (0.7, 0.1, 0.9),
    "orange": (1.0, 0.55, 0.05),
}

_CELL_ORIGIN = {  # (row, col) pixel origins of the four quadrants
    "top left": (0, 0),
    "top right": (0, CELL),
    "bottom left": (CELL, 0),
    "bottom right": (CELL, CELL),
}


@dataclass
class SceneObject:
    color: str
    shape: str
    position: str  # one of POSITIONS


@dataclass
class Scene:
    """A fully described image: up to four objects, one per quadrant."""

    objects: list[SceneObject] = field(default_factory=list)

    def occupied(self) -> set[str]:
        return {o.position for o in self.objects}

    def render(self) -> np.ndarray:
        img = np.zeros((IMG_SIZE, IMG_SIZE, 3), dtype=np.float32)
        for obj in self.objects:
            glyph = _GLYPHS[obj.shape]
            r0, c0 = _CELL_ORIGIN[obj.position]
            rgb = _RGB[obj.color]
            for r in range(CELL):
                for c in range(CELL):
                    if glyph[r][c] == "#":
                        img[r0 + r, c0 + c, :] = rgb
        return img


def random_scene(rng: np.random.Generator, min_objects: int = 1, max_objects: int = 3) -> Scene:
    n = int(rng.integers(min_objects, max_objects + 1))
    positions = list(rng.permutation(POSITIONS))[:n]
    objs = [
        SceneObject(
            color=COLORS[int(rng.integers(len(COLORS)))],
            shape=SHAPES[int(rng.integers(len(SHAPES)))],
            position=str(p),
        )
        for p in positions
    ]
    # canonical ordering: raster order of quadrants, so captions are
    # deterministic functions of the scene
    order = {p: i for i, p in enumerate(POSITIONS)}
    objs.sort(key=lambda o: order[o.position])
    return Scene(objs)


# ---------------------------------------------------------------------------
# Grammar: captions / QA with multiple equivalent phrasings
# ---------------------------------------------------------------------------

def _obj_phrase(o: SceneObject) -> str:
    return f"a {o.color} {o.shape} in the {o.position}"


def caption(scene: Scene, style: int) -> str:
    """Three equivalent caption templates.  The target VLM is trained on a
    mixture of styles; the canonical dataset label is always style 0.  The
    divergence between what the target *says* and what the dataset *labels*
    is exactly the distribution gap SDViT closes."""
    parts = [_obj_phrase(o) for o in scene.objects]
    if style == 0:
        body = " and ".join(parts)
        return f"the image shows {body} ."
    if style == 1:
        body = " and ".join(parts)
        return f"in this picture you can see {body} ."
    body = " and ".join(parts)
    return f"the scene contains {body} ."


def question_color(scene: Scene, rng: np.random.Generator) -> tuple[str, str]:
    o = scene.objects[int(rng.integers(len(scene.objects)))]
    q = f"what color is the {o.shape} ?"
    a = f"the {o.shape} in the {o.position} is {o.color} ."
    return q, a


def question_shape(scene: Scene, rng: np.random.Generator) -> tuple[str, str]:
    o = scene.objects[int(rng.integers(len(scene.objects)))]
    q = f"what shape is in the {o.position} ?"
    a = f"there is a {o.color} {o.shape} in the {o.position} ."
    return q, a


def question_count(scene: Scene, rng: np.random.Generator) -> tuple[str, str]:
    color = COLORS[int(rng.integers(len(COLORS)))]
    n = sum(1 for o in scene.objects if o.color == color)
    q = f"how many shapes are {color} ?"
    if n == 0:
        a = f"there are no {color} shapes in the image ."
    else:
        a = f"there are {NUMBER_WORDS[n]} {color} shapes in the image ."
    return q, a


def question_where(scene: Scene, rng: np.random.Generator) -> tuple[str, str]:
    o = scene.objects[int(rng.integers(len(scene.objects)))]
    q = f"where is the {o.color} {o.shape} ?"
    a = f"the {o.color} {o.shape} is located in the {o.position} ."
    return q, a


def gqa_answer(scene: Scene, rng: np.random.Generator) -> tuple[str, str]:
    """GQA analog: a reasoning-style answer that first enumerates then
    concludes (mirrors the paper's GQA prompt asking for step-by-step
    reasoning)."""
    o = scene.objects[int(rng.integers(len(scene.objects)))]
    q = f"question : what color is the {o.shape} ? explain your reasoning step by step ."
    steps = f"first i look at the {o.position} region . i identify a {o.shape} there ."
    concl = f"therefore the answer is {o.color} ."
    return q, f"{steps} {concl}"


_QA_GENERATORS = [question_color, question_shape, question_count, question_where]


def instruct_sample(scene: Scene, rng: np.random.Generator, style: int) -> tuple[str, str]:
    """LLaVA-Instruct analog: mixture of captioning requests and QA."""
    kind = int(rng.integers(0, 5))
    if kind == 0:
        return "describe the image briefly .", caption(scene, style)
    if kind == 1:
        return "tell me about the visible objects .", caption(scene, style)
    gen = _QA_GENERATORS[int(rng.integers(len(_QA_GENERATORS)))]
    return gen(scene, rng)


COCO_PROMPT = (
    "describe the image in detail . please provide a comprehensive "
    "description of every object and its location ."
)
WILD_PROMPT = "look at this picture and tell me what you see in the scene ."
GQA_PREAMBLE = "answer the question with reasoning ."


def coco_sample(scene: Scene, style: int) -> tuple[str, str]:
    return COCO_PROMPT, caption(scene, style)


def wild_sample(scene: Scene, rng: np.random.Generator, style: int) -> tuple[str, str]:
    # open-ended: caption plus one observation sentence
    o = scene.objects[int(rng.integers(len(scene.objects)))]
    extra = f"the {o.shape} in the {o.position} is {o.color} ."
    return WILD_PROMPT, f"{caption(scene, style)} {extra}"


TASKS = ["instruct", "wild", "gqa", "coco"]


def task_sample(task: str, scene: Scene, rng: np.random.Generator, style: int) -> tuple[str, str]:
    if task == "instruct":
        return instruct_sample(scene, rng, style)
    if task == "wild":
        return wild_sample(scene, rng, style)
    if task == "gqa":
        return gqa_answer(scene, rng)
    if task == "coco":
        return coco_sample(scene, style)
    raise ValueError(f"unknown task {task!r}")


# ---------------------------------------------------------------------------
# Dataset assembly
# ---------------------------------------------------------------------------

@dataclass
class Example:
    image: np.ndarray  # (16,16,3) f32
    prompt_ids: list[int]
    answer_ids: list[int]
    task: str

    def full_ids(self) -> list[int]:
        """Training sequence: <bos> prompt <sep> answer <eos>."""
        return [BOS_ID] + self.prompt_ids + [SEP_ID] + self.answer_ids + [EOS_ID]


def make_example(task: str, rng: np.random.Generator, style_mix: bool) -> Example:
    scene = random_scene(rng)
    style = int(rng.integers(0, 3)) if style_mix else 0
    prompt, answer = task_sample(task, scene, rng, style)
    return Example(
        image=scene.render(),
        prompt_ids=encode(prompt),
        answer_ids=encode(answer),
        task=task,
    )


def make_dataset(
    n: int,
    seed: int,
    tasks: list[str] | None = None,
    style_mix: bool = True,
) -> list[Example]:
    """Deterministic dataset.  ``style_mix=True`` trains the target on all
    caption phrasings (creating idiosyncrasy); ``style_mix=False`` produces
    canonical fixed labels (what MASSV-w/o-SDViT fine-tunes on)."""
    rng = np.random.default_rng(seed)
    tasks = tasks or TASKS
    return [make_example(tasks[i % len(tasks)], rng, style_mix) for i in range(n)]


def pretrain_pairs(n: int, seed: int) -> list[Example]:
    """LLaVA-Pretrain analog: pure image->caption pairs for projector
    pretraining (phase 1).  Prompt is empty: the model learns visual
    grounding, not instruction following."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        scene = random_scene(rng)
        style = int(rng.integers(0, 3))
        out.append(
            Example(
                image=scene.render(),
                prompt_ids=encode("describe the image briefly ."),
                answer_ids=encode(caption(scene, style)),
                task="pretrain",
            )
        )
    return out


# ---------------------------------------------------------------------------
# Export helpers (consumed by the Rust side)
# ---------------------------------------------------------------------------

def vocab_json() -> str:
    return json.dumps(
        {
            "tokens": VOCAB,
            "pad_id": PAD_ID,
            "bos_id": BOS_ID,
            "eos_id": EOS_ID,
            "sep_id": SEP_ID,
            "img_id": IMG_ID,
        },
        indent=1,
    )


def eval_set_json(task: str, n: int, seed: int) -> str:
    """Fixed eval prompts with rendered images, consumed by rust/workload."""
    rng = np.random.default_rng(seed)
    items = []
    for _ in range(n):
        scene = random_scene(rng)
        prompt, reference = task_sample(task, scene, rng, style=0)
        items.append(
            {
                "task": task,
                "prompt": prompt,
                "reference": reference,
                "image": [round(float(v), 4) for v in scene.render().reshape(-1)],
            }
        )
    return json.dumps({"task": task, "items": items})
