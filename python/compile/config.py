"""Model/system configuration for the MASSV reproduction.

The model zoo mirrors the paper's two families and two sizes per family
(DESIGN.md section 5).  ``MASSV_FAST=1`` shrinks training for smoke tests;
reported numbers always come from the default profile.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field

from . import shapeworld

# Sequence budget (shared by every model and the Rust runtime via manifest)
N_VISUAL = 4  # visual tokens: 8x8 patches == the four scene quadrants
P_MAX = 32  # max text prompt tokens (incl. <bos>/<sep>)
GEN_MAX = 48  # max generated tokens
GAMMA = 5  # speculation length (paper: gamma = 5)
# Slack so a gamma-token speculation never overruns the cache even at the
# generation cap; rounded up to a multiple of the kernel block (32).
T_MAX = ((N_VISUAL + P_MAX + GEN_MAX + GAMMA + 1 + 31) // 32) * 32  # 128
WINDOW = 16  # sliding-window width for the gemsim family

FAST = os.environ.get("MASSV_FAST", "0") == "1"


@dataclass(frozen=True)
class VisionConfig:
    # patch == 8 aligns each visual token with one scene quadrant, which is
    # what makes visual grounding learnable at this model scale (the
    # grounding-emergence experiment in EXPERIMENTS.md section Training).
    patch: int = 8
    d_vis: int = 64
    n_layers: int = 2
    n_heads: int = 4
    d_ffn: int = 128

    @property
    def d_head(self) -> int:
        return self.d_vis // self.n_heads

    @property
    def n_patches(self) -> int:
        side = shapeworld.IMG_SIZE // self.patch
        return side * side

    @property
    def d_patch(self) -> int:
        return self.patch * self.patch * 3


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "qwensim" (global attention) | "gemsim" (interleaved SWA)
    role: str  # "target" | "draft"
    paper_analog: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ffn: int
    vocab: int = shapeworld.VOCAB_SIZE
    window: int | None = None  # sliding window width on odd layers
    t_max: int = T_MAX
    p_max: int = P_MAX
    n_visual: int = N_VISUAL
    vision: VisionConfig = field(default_factory=VisionConfig)

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def layer_window(self, layer: int) -> int | None:
        """gemsim interleaves sliding-window attention on odd layers,
        mirroring Gemma3's interleaved local/global pattern."""
        if self.family == "gemsim" and layer % 2 == 1:
            return self.window or WINDOW
        return None


def _cfg(name, family, role, analog, d, l, h, f) -> ModelConfig:
    if FAST:
        d, l, f = max(d // 2, 24), max(l - 1, 1), max(f // 2, 48)
    window = WINDOW if family == "gemsim" else None
    return ModelConfig(
        name=name, family=family, role=role, paper_analog=analog,
        d_model=d, n_layers=l, n_heads=4, d_ffn=f, window=window,
    )


MODELS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _cfg("qwensim-L", "qwensim", "target", "Qwen2.5-VL 7B Instruct", 96, 3, 4, 192),
        _cfg("qwensim-XL", "qwensim", "target", "Qwen2.5-VL 32B Instruct", 128, 4, 4, 256),
        _cfg("gemsim-L", "gemsim", "target", "Gemma3-12B IT", 96, 3, 4, 192),
        _cfg("gemsim-XL", "gemsim", "target", "Gemma3-27B IT", 128, 4, 4, 256),
        _cfg("qwensim-S", "qwensim", "draft", "Qwen2.5-1.5B Instruct", 48, 2, 4, 96),
        _cfg("gemsim-S", "gemsim", "draft", "Gemma3-1B IT", 48, 2, 4, 96),
    ]
}

TARGETS = [n for n, c in MODELS.items() if c.role == "target"]
DRAFTS = [n for n, c in MODELS.items() if c.role == "draft"]
# the "aligned" target each drafter is trained against (paper: 7B / 12B);
# XL variants reuse the same drafter (the generalization experiment).
ALIGN_TARGET = {"qwensim-S": "qwensim-L", "gemsim-S": "gemsim-L"}
FAMILY_TARGETS = {
    "qwensim": ["qwensim-L", "qwensim-XL"],
    "gemsim": ["gemsim-L", "gemsim-XL"],
}
DRAFT_VARIANTS = ["baseline", "massv_wo_sdvit", "massv"]


@dataclass(frozen=True)
class TrainConfig:
    # dataset sizes
    n_target_train: int = 512 if FAST else 4096
    n_pretrain_pairs: int = 256 if FAST else 2048
    n_finetune: int = 256 if FAST else 2048
    n_text_pretrain: int = 512 if FAST else 3072
    # optimization
    target_epochs: int = 1 if FAST else 12
    pretrain_epochs: int = 1 if FAST else 6
    finetune_epochs: int = 1 if FAST else 6
    batch_size: int = 32 if FAST else 64
    lr_target: float = 1e-3
    lr_pretrain: float = 1e-3  # paper appendix: projector pretrain lr 1e-3
    lr_finetune: float = 2e-4  # paper: 2e-5 for 1.5B; scaled for toy models
    seed: int = 1234
    # SDViT generation (paper: top-p across temperatures for diversity)
    sdd_temperatures: tuple[float, ...] = (0.7, 1.0)
    sdd_top_p: float = 0.9


TRAIN = TrainConfig()

EVAL_SEED = 20250710
EVAL_N_PER_TASK = 16 if FAST else 50
