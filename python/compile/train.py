"""MASSV two-phase training pipeline (build time only).

Reproduces Section 3.2 end to end, on the shape-world substitution:

  0. Train target VLMs (both families, L and XL) on style-mixed multimodal
     data -- the analog of the released Qwen2.5-VL / Gemma3 checkpoints.
     Style mixing gives each target idiosyncratic phrasing preferences, the
     distribution gap SDViT is designed to close.
  1. Pretrain text-only SLMs (the paper's off-the-shelf 1.5B/1B drafters)
     and fine-tune them on text-only transcripts -> ``baseline`` drafter.
  2. Phase 1 (Eq. 3): multimodal projector pretraining on image-caption
     pairs, vision encoder + SLM frozen.
  3. Phase 2:
       a. fixed-label visual instruction tuning  -> ``massv_wo_sdvit``
       b. SDViT (Eq. 4-5): fine-tune on responses sampled from the target
          VLM (top-p, multiple temperatures)     -> ``massv``

Artifacts: pickled parameter checkpoints under artifacts/params/ and the
Figure-5 loss curves in artifacts/training_curves.json.

Optimizer: hand-written Adam (optax is not available offline).
"""

from __future__ import annotations

import json
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model, selfdistill, shapeworld
from .config import (
    ALIGN_TARGET,
    GEN_MAX,
    MODELS,
    P_MAX,
    TRAIN,
    ModelConfig,
)

S_TXT = P_MAX + GEN_MAX  # padded text length of a training sequence

# ---------------------------------------------------------------------------
# Batching
# ---------------------------------------------------------------------------


def assemble_sequence(ex: shapeworld.Example) -> tuple[np.ndarray, np.ndarray, int]:
    """[<bos> prompt <sep> answer <eos> <pad>...] plus the supervision mask
    (answer tokens + <eos>).  Returns (tokens [S_TXT], mask [S_TXT], prompt_len)
    where prompt_len counts <bos> prompt <sep>."""
    ids = [shapeworld.BOS_ID] + ex.prompt_ids + [shapeworld.SEP_ID]
    prompt_len = len(ids)
    ids = ids + ex.answer_ids + [shapeworld.EOS_ID]
    if len(ids) > S_TXT:
        raise ValueError(f"sequence too long: {len(ids)} > {S_TXT}")
    toks = np.full(S_TXT, shapeworld.PAD_ID, dtype=np.int32)
    toks[: len(ids)] = ids
    mask = np.zeros(S_TXT, dtype=np.float32)
    mask[prompt_len : len(ids)] = 1.0
    return toks, mask, prompt_len


def make_batches(
    examples: list[shapeworld.Example],
    batch_size: int,
    rng: np.random.Generator,
    *,
    supervise_all: bool = False,
    with_images: bool = True,
):
    """Yield dict batches.  ``supervise_all`` turns on full-LM supervision
    (SLM pretraining); otherwise only answer tokens are supervised."""
    order = rng.permutation(len(examples))
    for i in range(0, len(examples) - batch_size + 1, batch_size):
        idx = order[i : i + batch_size]
        toks, masks, imgs = [], [], []
        for j in idx:
            t, m, _ = assemble_sequence(examples[j])
            if supervise_all:
                m = (t != shapeworld.PAD_ID).astype(np.float32)
            toks.append(t)
            masks.append(m)
            if with_images:
                imgs.append(examples[j].image)
        batch = {
            "tokens": jnp.asarray(np.stack(toks)),
            "mask": jnp.asarray(np.stack(masks)),
        }
        if with_images:
            batch["images"] = jnp.asarray(np.stack(imgs))
        yield batch


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    z = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2**t), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, {"m": m, "v": v, "t": t}


def freeze_scale(grads: dict, trainable: dict[str, bool]) -> dict:
    """Zero the gradient of frozen top-level components ('vision', 'proj',
    'lm') -- how the snowflake/flame split of Figure 2 is realized."""
    return {
        k: jax.tree.map(lambda g: g if trainable.get(k, True) else jnp.zeros_like(g), sub)
        for k, sub in grads.items()
    }


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------


def _loss_mm(params, cfg, batch):
    logits = model.train_logits_mm(params, cfg, batch["images"], batch["tokens"])
    return model.next_token_loss(logits, batch["tokens"], batch["mask"])


def _loss_text(params, cfg, batch):
    logits = model.train_logits_text(params, cfg, batch["tokens"])
    return model.next_token_loss(logits, batch["tokens"], batch["mask"])


def train_phase(
    params: dict,
    cfg: ModelConfig,
    examples: list[shapeworld.Example],
    *,
    epochs: int,
    lr: float,
    multimodal: bool,
    trainable: dict[str, bool] | None = None,
    supervise_all: bool = False,
    seed: int = 0,
    phase_name: str = "",
    curves: list | None = None,
    log_every: int = 10,
) -> dict:
    """Generic phase runner used by every stage of the pipeline."""
    loss_fn = _loss_mm if multimodal else _loss_text
    trainable = trainable or {}

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
        grads = freeze_scale(grads, trainable)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(seed)
    it, t0 = 0, time.time()
    loss = float("nan")
    batch_size = min(TRAIN.batch_size, len(examples))  # tiny-dataset safety
    for ep in range(epochs):
        for batch in make_batches(
            examples, batch_size, rng,
            supervise_all=supervise_all, with_images=multimodal,
        ):
            params, opt, loss = step(params, opt, batch)
            if curves is not None and it % log_every == 0:
                curves.append({"phase": phase_name, "step": it, "loss": float(loss)})
            it += 1
    if curves is not None:
        curves.append({"phase": phase_name, "step": it, "loss": float(loss)})
    print(f"  [{phase_name}] {it} steps, final loss {float(loss):.4f}, "
          f"{time.time() - t0:.1f}s", flush=True)
    return params


# ---------------------------------------------------------------------------
# Checkpoint I/O
# ---------------------------------------------------------------------------


def save_params(path: str, params: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        pickle.dump(jax.tree.map(np.asarray, params), f)


def load_params(path: str) -> dict:
    with open(path, "rb") as f:
        return jax.tree.map(jnp.asarray, pickle.load(f))


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------


def train_all(outdir: str) -> None:
    """Train every model in DESIGN.md section 5 and dump checkpoints."""
    pdir = os.path.join(outdir, "params")
    os.makedirs(pdir, exist_ok=True)
    curves: list[dict] = []

    target_data = shapeworld.make_dataset(TRAIN.n_target_train, TRAIN.seed, style_mix=True)
    pre_pairs = shapeworld.pretrain_pairs(TRAIN.n_pretrain_pairs, TRAIN.seed + 1)
    ft_data = shapeworld.make_dataset(TRAIN.n_finetune, TRAIN.seed + 2, style_mix=False)
    text_data = shapeworld.make_dataset(TRAIN.n_text_pretrain, TRAIN.seed + 3, style_mix=True)

    # ---- 0. target VLMs --------------------------------------------------
    targets: dict[str, dict] = {}
    for name, cfg in MODELS.items():
        if cfg.role != "target":
            continue
        print(f"training target {name} ({cfg.paper_analog} analog)", flush=True)
        params = model.init_target_params(cfg, TRAIN.seed + hash(name) % 1000)
        params = train_phase(
            params, cfg, target_data,
            epochs=TRAIN.target_epochs, lr=TRAIN.lr_target, multimodal=True,
            seed=TRAIN.seed, phase_name=f"target/{name}", curves=curves,
        )
        targets[name] = params
        save_params(os.path.join(pdir, f"target_{name}.pkl"), params)

    # ---- 1. SLM backbones + baseline drafters ----------------------------
    for dname, align in ALIGN_TARGET.items():
        cfg = MODELS[dname]
        tname = align
        tcfg = MODELS[tname]
        fam = cfg.family
        print(f"drafter pipeline for {dname} (family {fam}, target {tname})", flush=True)

        # 1a. text pretraining of the off-the-shelf SLM
        slm = model.init_target_params(cfg, TRAIN.seed + 77 + hash(dname) % 97)
        slm = train_phase(
            slm, cfg, text_data,
            epochs=TRAIN.target_epochs, lr=TRAIN.lr_target, multimodal=False,
            trainable={"vision": False, "proj": False, "lm": True},
            supervise_all=True, seed=TRAIN.seed + 4,
            phase_name=f"slm_pretrain/{dname}", curves=curves,
        )

        # 1b. baseline: text-only fine-tune on fixed instruct transcripts
        # (Gagrani et al. text-only drafting baseline)
        baseline = {k: v for k, v in slm.items()}
        baseline = train_phase(
            baseline, cfg, ft_data,
            epochs=TRAIN.finetune_epochs, lr=TRAIN.lr_finetune, multimodal=False,
            trainable={"vision": False, "proj": False, "lm": True},
            seed=TRAIN.seed + 5, phase_name=f"baseline/{dname}", curves=curves,
        )
        save_params(os.path.join(pdir, f"draft_{dname}_baseline.pkl"), baseline)

        # ---- 2. Phase 1: projector pretraining (Eq. 3) --------------------
        drafter = model.init_drafter_params(
            cfg, targets[tname]["vision"], slm["lm"], TRAIN.seed + 6
        )
        drafter = train_phase(
            drafter, cfg, pre_pairs,
            epochs=TRAIN.pretrain_epochs, lr=TRAIN.lr_pretrain, multimodal=True,
            trainable={"vision": False, "proj": True, "lm": False},
            seed=TRAIN.seed + 7, phase_name=f"phase1_projector/{dname}", curves=curves,
        )
        save_params(os.path.join(pdir, f"draft_{dname}_phase1.pkl"), drafter)

        # ---- 3a. Phase 2 without SDViT: fixed-label fine-tune -------------
        wo_sdvit = train_phase(
            dict(drafter), cfg, ft_data,
            epochs=TRAIN.finetune_epochs, lr=TRAIN.lr_finetune, multimodal=True,
            trainable={"vision": False, "proj": True, "lm": True},
            seed=TRAIN.seed + 8, phase_name=f"phase2_fixed/{dname}", curves=curves,
        )
        save_params(os.path.join(pdir, f"draft_{dname}_massv_wo_sdvit.pkl"), wo_sdvit)

        # ---- 3b. Phase 2 with SDViT (Eq. 4-5) ------------------------------
        print(f"  generating self-distilled dataset from {tname}", flush=True)
        sdd_data = selfdistill.distill_dataset(
            targets[tname], tcfg, ft_data,
            temperatures=TRAIN.sdd_temperatures, top_p=TRAIN.sdd_top_p,
            seed=TRAIN.seed + 9,
        )
        massv = train_phase(
            dict(drafter), cfg, sdd_data,
            epochs=TRAIN.finetune_epochs, lr=TRAIN.lr_finetune, multimodal=True,
            trainable={"vision": False, "proj": True, "lm": True},
            seed=TRAIN.seed + 10, phase_name=f"phase2_sdvit/{dname}", curves=curves,
        )
        save_params(os.path.join(pdir, f"draft_{dname}_massv.pkl"), massv)

    with open(os.path.join(outdir, "training_curves.json"), "w") as f:
        json.dump({"curves": curves}, f)
    print("training complete", flush=True)
