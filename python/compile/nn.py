"""Layer-2 building blocks: RMSNorm, RoPE, cached attention, MLP, blocks.

All functions are pure (params are nested dicts of jnp arrays) and written
unbatched over ``[S, d]`` activations; training vmaps them over the batch
axis.  The cached-attention path routes through the Layer-1 Pallas kernel
(``use_kernel=True``, the AOT inference path) or the pure-jnp reference
(training / oracle path); python/tests/test_model.py asserts the two paths
agree, which is the L1<->L2 integration contract.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, VisionConfig
from .kernels.attention import fused_attention
from .kernels.ref import attention_reference

# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _dense(rng, d_in, d_out, scale=0.02):
    w = rng.normal(0.0, scale, size=(d_in, d_out)).astype(np.float32)
    return {"w": jnp.asarray(w), "b": jnp.zeros((d_out,), jnp.float32)}


def _block_params(rng, d, h, dh, dff):
    return {
        "ln1": {"g": jnp.ones((d,), jnp.float32)},
        "wq": _dense(rng, d, h * dh),
        "wk": _dense(rng, d, h * dh),
        "wv": _dense(rng, d, h * dh),
        "wo": _dense(rng, h * dh, d),
        "ln2": {"g": jnp.ones((d,), jnp.float32)},
        "w1": _dense(rng, d, dff),
        "w2": _dense(rng, dff, d),
    }


def init_lm_params(cfg: ModelConfig, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "embed": jnp.asarray(
            rng.normal(0.0, 0.02, size=(cfg.vocab, cfg.d_model)).astype(np.float32)
        ),
        "blocks": [
            _block_params(rng, cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ffn)
            for _ in range(cfg.n_layers)
        ],
        "ln_f": {"g": jnp.ones((cfg.d_model,), jnp.float32)},
        "head": _dense(rng, cfg.d_model, cfg.vocab),
    }


def init_vision_params(vc: VisionConfig, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "patch": _dense(rng, vc.d_patch, vc.d_vis),
        "pos": jnp.asarray(
            rng.normal(0.0, 0.02, size=(vc.n_patches, vc.d_vis)).astype(np.float32)
        ),
        "blocks": [
            _block_params(rng, vc.d_vis, vc.n_heads, vc.d_head, vc.d_ffn)
            for _ in range(vc.n_layers)
        ],
        "ln_f": {"g": jnp.ones((vc.d_vis,), jnp.float32)},
    }


def init_projector_params(d_vis: int, d_model: int, seed: int) -> dict:
    """LLaVA-style 2-layer MLP projector (Eq. 2: R^{d_vis} -> R^{d_emb^q});
    randomly initialized per Section 3.1."""
    rng = np.random.default_rng(seed)
    return {
        "fc1": _dense(rng, d_vis, d_model),
        "fc2": _dense(rng, d_model, d_model),
    }


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------


def dense(p, x):
    return x @ p["w"] + p["b"]


def rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * p["g"]


def gelu(x):
    return jax.nn.gelu(x)


def rope(x: jnp.ndarray, positions: jnp.ndarray, base: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding.  x: [H, S, Dh] (Dh even), positions: [S]."""
    h, s, dh = x.shape
    half = dh // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / half)  # [half]
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)


def _split_heads(x, h):
    s, hd = x.shape
    return x.reshape(s, h, hd // h).transpose(1, 0, 2)  # [H, S, Dh]


def _merge_heads(x):
    h, s, dh = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * dh)


# ---------------------------------------------------------------------------
# Transformer block with KV cache
# ---------------------------------------------------------------------------


def attn_cached(
    p: dict,
    x: jnp.ndarray,  # [S, d]
    kcache: jnp.ndarray,  # [H, T, Dh]
    vcache: jnp.ndarray,
    pos,  # scalar i32: absolute position of x[0]
    *,
    n_heads: int,
    window: int | None,
    use_kernel: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Project q/k/v, rotate, write the cache at [pos, pos+S), attend.

    Returns (y [S, d], kcache', vcache').  Stale cache entries beyond
    pos+S-1 are invisible under the causal mask (DESIGN.md section 3)."""
    s = x.shape[0]
    positions = pos + jnp.arange(s, dtype=jnp.int32)
    q = rope(_split_heads(dense(p["wq"], x), n_heads), positions)
    k = rope(_split_heads(dense(p["wk"], x), n_heads), positions)
    v = _split_heads(dense(p["wv"], x), n_heads)

    kcache = jax.lax.dynamic_update_slice(kcache, k, (0, pos, 0))
    vcache = jax.lax.dynamic_update_slice(vcache, v, (0, pos, 0))

    if use_kernel:
        out = fused_attention(q, kcache, vcache, pos, window=window)
    else:
        out = attention_reference(q, kcache, vcache, pos, window=window)
    y = dense(p["wo"], _merge_heads(out))
    return y, kcache, vcache


def block_cached(
    p, x, kcache, vcache, pos, *, n_heads, window, use_kernel
):
    a, kcache, vcache = attn_cached(
        p, rmsnorm(p["ln1"], x), kcache, vcache, pos,
        n_heads=n_heads, window=window, use_kernel=use_kernel,
    )
    x = x + a
    hmid = gelu(dense(p["w1"], rmsnorm(p["ln2"], x)))
    x = x + dense(p["w2"], hmid)
    return x, kcache, vcache


def lm_forward_cached(
    params: dict,
    cfg: ModelConfig,
    embeds: jnp.ndarray,  # [S, d] (token and/or visual embeddings)
    kv: jnp.ndarray,  # [L, 2, H, T, Dh]
    pos,
    *,
    use_kernel: bool,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run all blocks over S new positions, updating the packed KV cache.

    Returns (logits [S, V], kv')."""
    x = embeds
    new_kv = []
    for i, bp in enumerate(params["blocks"]):
        x, kc, vc = block_cached(
            bp, x, kv[i, 0], kv[i, 1], pos,
            n_heads=cfg.n_heads, window=cfg.layer_window(i), use_kernel=use_kernel,
        )
        new_kv.append(jnp.stack([kc, vc]))
    x = rmsnorm(params["ln_f"], x)
    logits = dense(params["head"], x)
    return logits, jnp.stack(new_kv)


def empty_kv(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.zeros(
        (cfg.n_layers, 2, cfg.n_heads, cfg.t_max, cfg.d_head), jnp.float32
    )


# ---------------------------------------------------------------------------
# Vision encoder + projector
# ---------------------------------------------------------------------------


def patchify(image: jnp.ndarray, patch: int) -> jnp.ndarray:
    """[16,16,3] -> [n_patches, patch*patch*3] in raster order."""
    hh, ww, c = image.shape
    gh, gw = hh // patch, ww // patch
    x = image.reshape(gh, patch, gw, patch, c)
    x = x.transpose(0, 2, 1, 3, 4).reshape(gh * gw, patch * patch * c)
    return x


def vision_encode(vp: dict, vc: VisionConfig, image: jnp.ndarray) -> jnp.ndarray:
    """Frozen target vision encoder phi_I (Section 3.1): bidirectional
    transformer over patch embeddings.  Returns [n_patches, d_vis]."""
    x = dense(vp["patch"], patchify(image, vc.patch)) + vp["pos"]
    for bp in vp["blocks"]:
        h = rmsnorm(bp["ln1"], x)
        q = _split_heads(dense(bp["wq"], h), vc.n_heads)
        k = _split_heads(dense(bp["wk"], h), vc.n_heads)
        v = _split_heads(dense(bp["wv"], h), vc.n_heads)
        out = attention_reference(q, k, v, 0, window=None, causal=False)
        x = x + dense(bp["wo"], _merge_heads(out))
        x = x + dense(bp["w2"], gelu(dense(bp["w1"], rmsnorm(bp["ln2"], x))))
    return rmsnorm(vp["ln_f"], x)


def project_visual(pp: dict, feats: jnp.ndarray) -> jnp.ndarray:
    """g_psi: map vision features into the LM embedding space (Eq. 2)."""
    return dense(pp["fc2"], gelu(dense(pp["fc1"], feats)))


# ---------------------------------------------------------------------------
# Batched training forward (full sequence, no cache)
# ---------------------------------------------------------------------------


def _full_attn_batched(p, x, positions, *, n_heads, window):
    """x: [B, S, d]; full causal self-attention (training path, jnp only)."""

    def one(xb):
        q = rope(_split_heads(dense(p["wq"], xb), n_heads), positions)
        k = rope(_split_heads(dense(p["wk"], xb), n_heads), positions)
        v = _split_heads(dense(p["wv"], xb), n_heads)
        out = attention_reference(q, k, v, 0, window=window)
        return dense(p["wo"], _merge_heads(out))

    return jax.vmap(one)(x)


def lm_forward_train(
    params: dict, cfg: ModelConfig, embeds: jnp.ndarray  # [B, S, d]
) -> jnp.ndarray:
    """Training forward over full (padded) sequences.  Returns [B, S, V]."""
    b, s, _ = embeds.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = embeds
    for i, bp in enumerate(params["blocks"]):
        a = _full_attn_batched(
            bp, rmsnorm(bp["ln1"], x), positions,
            n_heads=cfg.n_heads, window=cfg.layer_window(i),
        )
        x = x + a
        x = x + dense(bp["w2"], gelu(dense(bp["w1"], rmsnorm(bp["ln2"], x))))
    x = rmsnorm(params["ln_f"], x)
    return dense(params["head"], x)
