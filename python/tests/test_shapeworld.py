"""Shape-world data generator: grammar closure, determinism, rendering."""

import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import shapeworld as sw


def test_vocab_has_no_duplicates():
    assert len(sw.VOCAB) == len(set(sw.VOCAB))
    assert sw.VOCAB[:5] == sw.SPECIALS


def test_encode_decode_roundtrip():
    s = "the image shows a red circle in the top left ."
    assert sw.decode(sw.encode(s)) == s


def test_encode_rejects_oov():
    with pytest.raises(KeyError):
        sw.encode("the flying spaghetti monster")


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), task=st.sampled_from(sw.TASKS))
def test_grammar_closed_over_vocab(seed, task):
    """Every sentence the grammar can emit must tokenize (no OOV ever)."""
    rng = np.random.default_rng(seed)
    ex = sw.make_example(task, rng, style_mix=True)
    assert ex.prompt_ids and ex.answer_ids
    assert all(0 <= i < sw.VOCAB_SIZE for i in ex.full_ids())


def test_dataset_deterministic():
    a = sw.make_dataset(20, seed=5)
    b = sw.make_dataset(20, seed=5)
    for x, y in zip(a, b):
        assert x.prompt_ids == y.prompt_ids
        assert x.answer_ids == y.answer_ids
        np.testing.assert_array_equal(x.image, y.image)


def test_dataset_seed_changes_content():
    a = sw.make_dataset(20, seed=5)
    b = sw.make_dataset(20, seed=6)
    assert any(x.answer_ids != y.answer_ids for x, y in zip(a, b))


def test_render_shape_and_range():
    rng = np.random.default_rng(0)
    img = sw.random_scene(rng).render()
    assert img.shape == (16, 16, 3) and img.dtype == np.float32
    assert img.min() >= 0.0 and img.max() <= 1.0


def test_render_distinguishes_scenes():
    """Different (color, shape, quadrant) triples must render differently --
    otherwise visual grounding is unlearnable."""
    seen = {}
    for color in sw.COLORS[:3]:
        for shape in sw.SHAPES[:3]:
            for posn in sw.POSITIONS[:2]:
                scene = sw.Scene([sw.SceneObject(color, shape, posn)])
                key = scene.render().tobytes()
                assert key not in seen, (color, shape, posn, seen.get(key))
                seen[key] = (color, shape, posn)


def test_empty_quadrants_are_black():
    scene = sw.Scene([sw.SceneObject("red", "circle", "top left")])
    img = scene.render()
    assert img[8:, 8:, :].sum() == 0.0  # bottom right untouched


def test_caption_styles_are_distinct_but_consistent():
    rng = np.random.default_rng(1)
    scene = sw.random_scene(rng)
    caps = [sw.caption(scene, s) for s in range(3)]
    assert len(set(caps)) == 3
    # all styles describe the same objects in the same order
    for o in scene.objects:
        for c in caps:
            assert f"{o.color} {o.shape}" in c


def test_count_question_answer_is_correct():
    scene = sw.Scene(
        [
            sw.SceneObject("red", "circle", "top left"),
            sw.SceneObject("red", "square", "top right"),
            sw.SceneObject("blue", "star", "bottom left"),
        ]
    )
    rng = np.random.default_rng(0)
    for _ in range(50):
        q, a = sw.question_count(scene, rng)
        color = q.split()[4]
        n = sum(1 for o in scene.objects if o.color == color)
        if n == 0:
            assert "no" in a.split()
        else:
            assert sw.NUMBER_WORDS[n] in a.split()


def test_sequence_budget():
    """Every generated example must fit the AOT sequence budget."""
    from compile.config import GEN_MAX, P_MAX

    rng = np.random.default_rng(9)
    for i in range(400):
        ex = sw.make_example(sw.TASKS[i % 4], rng, style_mix=True)
        assert len(ex.prompt_ids) + 2 <= P_MAX, ex.prompt_ids
        assert len(ex.answer_ids) + 1 <= GEN_MAX, ex.answer_ids


def test_eval_set_json_schema():
    blob = json.loads(sw.eval_set_json("coco", 3, seed=1))
    assert blob["task"] == "coco"
    assert len(blob["items"]) == 3
    it = blob["items"][0]
    assert len(it["image"]) == 16 * 16 * 3
    sw.encode(it["prompt"])  # must tokenize
    sw.encode(it["reference"])


def test_vocab_json_schema():
    blob = json.loads(sw.vocab_json())
    assert blob["tokens"][blob["pad_id"]] == "<pad>"
    assert blob["tokens"][blob["eos_id"]] == "<eos>"
    assert len(blob["tokens"]) == sw.VOCAB_SIZE
