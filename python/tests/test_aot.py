"""AOT lowering: HLO text artifacts well-formed, manifest schema stable.

Uses an *untrained* tiny model so the test is fast and independent of the
full `make artifacts` run; the real artifacts are exercised by the Rust
integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model, shapeworld as sw
from compile.config import GAMMA, MODELS, P_MAX


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    outdir = str(tmp_path_factory.mktemp("aot"))
    cfg = MODELS["qwensim-S"]
    params = model.init_target_params(cfg, 0)
    entries = aot.lower_common(params, cfg, "toy", outdir, mm=True)
    return outdir, cfg, entries


def test_all_entry_points_emitted(lowered):
    outdir, _cfg, entries = lowered
    assert set(entries) == {"prefill_mm", "prefill_text", "verify", "decode", "draft"}
    for meta in entries.values():
        path = os.path.join(outdir, meta["file"])
        assert os.path.exists(path)
        assert meta["bytes"] > 1000


def test_hlo_text_is_parsable_hlo(lowered):
    outdir, _cfg, entries = lowered
    text = open(os.path.join(outdir, entries["verify"]["file"])).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # weights are baked: there must be large constants in the module
    assert "constant" in text


def test_verify_hlo_shapes(lowered):
    outdir, cfg, entries = lowered
    text = open(os.path.join(outdir, entries["verify"]["file"])).read()
    # input: gamma+1 tokens; output tuple (logits [gamma+1, V], kv)
    assert f"s32[{GAMMA + 1}]" in text
    assert f"f32[{GAMMA + 1},{cfg.vocab}]" in text


def test_prefill_hlo_shapes(lowered):
    outdir, cfg, entries = lowered
    text = open(os.path.join(outdir, entries["prefill_mm"]["file"])).read()
    assert "f32[16,16,3]" in text
    assert f"s32[{P_MAX}]" in text


def test_to_hlo_text_round_trips_numerics():
    """Lower a toy jax fn and check the HLO text still encodes the same
    function by reparsing constants (smoke for the interchange format)."""
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[4]" in text


def test_manifest_vocab_eval_written(tmp_path, monkeypatch):
    """Fast end-to-end of aot.main's export stage using pre-seeded params
    (skips training by planting checkpoints)."""
    outdir = str(tmp_path / "arts")
    pdir = os.path.join(outdir, "params")
    os.makedirs(pdir, exist_ok=True)
    from compile import train as trainmod
    from compile.config import ALIGN_TARGET, DRAFT_VARIANTS

    for name, cfg in MODELS.items():
        if cfg.role == "target":
            trainmod.save_params(
                os.path.join(pdir, f"target_{name}.pkl"),
                model.init_target_params(cfg, 1),
            )
    for dname in ALIGN_TARGET:
        cfg = MODELS[dname]
        for v in DRAFT_VARIANTS:
            trainmod.save_params(
                os.path.join(pdir, f"draft_{dname}_{v}.pkl"),
                model.init_target_params(cfg, 2),
            )
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out", outdir, "--skip-train"]
    )
    aot.main()

    manifest = json.load(open(os.path.join(outdir, "manifest.json")))
    assert manifest["schema"] == 1
    assert manifest["gamma"] == GAMMA
    assert len(manifest["targets"]) == 4
    assert len(manifest["drafters"]) == 6
    baseline = [d for d in manifest["drafters"] if d["variant"] == "baseline"]
    assert all(not d["multimodal"] for d in baseline)
    assert all("prefill_mm" not in d["entries"] for d in baseline)

    vocab = json.load(open(os.path.join(outdir, "vocab.json")))
    assert len(vocab["tokens"]) == sw.VOCAB_SIZE
    for task in sw.TASKS:
        ev = json.load(open(os.path.join(outdir, "eval", f"{task}.json")))
        assert len(ev["items"]) > 0
