"""Layer-1 correctness: the Pallas fused-attention kernel vs the pure-jnp
oracle (kernels/ref.py).  This is the CORE correctness signal for the
kernel; hypothesis sweeps shapes, positions, windows and dtypes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    fused_attention,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import attention_reference

ATOL = 2e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _check(h, s, t, d, pos, window, causal=True, dtype=jnp.float32, atol=ATOL):
    rng = np.random.default_rng(hash((h, s, t, d, pos, window or 0)) % 2**32)
    q = _rand(rng, h, s, d).astype(dtype)
    k = _rand(rng, h, t, d).astype(dtype)
    v = _rand(rng, h, t, d).astype(dtype)
    got = fused_attention(q, k, v, pos, window=window, causal=causal)
    want = attention_reference(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        pos, window=window, causal=causal,
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=atol, rtol=1e-4
    )


# ---------------------------------------------------------------------------
# The exact shapes the model zoo uses
# ---------------------------------------------------------------------------

MODEL_SHAPES = [
    # (H, S, T, Dh): prefill mm (48 new), verify (6), decode (1), draft step
    (4, 48, 96, 24),
    (4, 48, 96, 12),
    (4, 6, 96, 24),
    (4, 6, 96, 32),
    (4, 1, 96, 12),
    (4, 1, 96, 24),
    (4, 80, 96, 24),  # full prefill incl. text
    (4, 96, 96, 32),
]


@pytest.mark.parametrize("h,s,t,d", MODEL_SHAPES)
@pytest.mark.parametrize("pos", [0, 17, 48])
@pytest.mark.parametrize("window", [None, 16])
def test_model_shapes(h, s, t, d, pos, window):
    if pos + s > t:
        pos = t - s
    _check(h, s, t, d, pos, window)


def test_non_causal_full_attention():
    # vision-encoder mode: every key visible
    _check(4, 16, 32, 12, 0, None, causal=False)


def test_decode_last_position():
    _check(4, 1, 96, 24, 95, None)
    _check(4, 1, 96, 24, 95, 16)


def test_stale_tail_is_invisible():
    """Entries beyond the causal horizon must not affect the output -- the
    property that makes speculative rejection rollback-free."""
    rng = np.random.default_rng(7)
    h, s, t, d, pos = 4, 6, 96, 24, 40
    q = _rand(rng, h, s, d)
    k = _rand(rng, h, t, d)
    v = _rand(rng, h, t, d)
    base = fused_attention(q, k, v, pos, window=None)
    # scribble garbage into the stale tail (positions > pos + s - 1)
    k2 = k.at[:, pos + s :, :].set(1e3)
    v2 = v.at[:, pos + s :, :].set(-1e3)
    got = fused_attention(q, k2, v2, pos, window=None)
    np.testing.assert_allclose(np.asarray(base), np.asarray(got), atol=0, rtol=0)


def test_window_equals_t_matches_global():
    rng = np.random.default_rng(3)
    h, s, t, d = 4, 8, 64, 16
    q, k, v = _rand(rng, h, s, d), _rand(rng, h, t, d), _rand(rng, h, t, d)
    a = fused_attention(q, k, v, 10, window=t)  # window covers everything
    b = fused_attention(q, k, v, 10, window=None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_window_one_attends_self_only():
    rng = np.random.default_rng(4)
    h, s, t, d = 2, 4, 32, 8
    q, k, v = _rand(rng, h, s, d), _rand(rng, h, t, d), _rand(rng, h, t, d)
    out = fused_attention(q, k, v, 5, window=1)
    # with window 1 each query sees exactly its own position: output == v@pos
    for i in range(s):
        np.testing.assert_allclose(
            np.asarray(out[:, i, :]), np.asarray(v[:, 5 + i, :]), atol=1e-5
        )


# ---------------------------------------------------------------------------
# Hypothesis sweep
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    h=st.integers(1, 4),
    s=st.integers(1, 33),
    tb=st.integers(1, 3),  # t = 32 * tb (kernel requires block_k multiple)
    d=st.sampled_from([8, 12, 16, 24]),
    pos_frac=st.floats(0.0, 1.0),
    window=st.sampled_from([None, 4, 16, 32]),
)
def test_hypothesis_sweep(h, s, tb, d, pos_frac, window):
    t = 32 * tb
    s = min(s, t)
    pos = int(pos_frac * (t - s))
    _check(h, s, t, d, pos, window)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 16), pos=st.integers(0, 40))
def test_hypothesis_bf16(s, pos):
    # bf16 inputs, f32 accumulation; looser tolerance
    _check(4, s, 64, 16, min(pos, 64 - s), None, dtype=jnp.bfloat16, atol=3e-2)


# ---------------------------------------------------------------------------
# Roofline bookkeeping (structure-level, see EXPERIMENTS.md section Perf)
# ---------------------------------------------------------------------------


def test_vmem_footprint_within_budget():
    # a TPU core has ~16 MiB of VMEM; every config we ship must fit easily
    for h, s, t, d in MODEL_SHAPES:
        fp = vmem_footprint_bytes(s, t, d, block_q=32, block_k=32)
        assert fp["total"] < 1 << 20, (h, s, t, d, fp)
        assert fp["total"] == sum(v for k, v in fp.items() if k != "total")


def test_mxu_estimate_monotone_in_tile():
    lo = mxu_utilization_estimate(dh=16, block_q=16, block_k=16)
    hi = mxu_utilization_estimate(dh=128, block_q=128, block_k=128)
    assert 0.0 < lo < hi <= 1.0
