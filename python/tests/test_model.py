"""Layer-2 model tests: kernel/ref path agreement (the L1<->L2 contract),
draft-scan semantics, KV staleness, RoPE properties, loss behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, nn, shapeworld as sw
from compile.config import MODELS, ModelConfig

CFG = MODELS["qwensim-L"]
GCFG = MODELS["gemsim-L"]
DCFG = MODELS["qwensim-S"]


@pytest.fixture(scope="module")
def tparams():
    return model.init_target_params(CFG, 11)


@pytest.fixture(scope="module")
def gparams():
    return model.init_target_params(GCFG, 12)


def _img(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(sw.random_scene(rng).render())


def _prompt(words="describe the image briefly .", p_max=None):
    ids = [sw.BOS_ID] + sw.encode(words) + [sw.SEP_ID]
    p_max = p_max or CFG.p_max
    out = np.full(p_max, sw.PAD_ID, np.int32)
    out[: len(ids)] = ids
    return jnp.asarray(out), len(ids)


# ---------------------------------------------------------------------------
# Kernel path == reference path (through the whole model)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfgname", ["qwensim-L", "gemsim-L", "qwensim-S", "gemsim-S"])
def test_prefill_kernel_matches_ref(cfgname):
    cfg = MODELS[cfgname]
    params = model.init_target_params(cfg, 3)
    ids, ln = _prompt(p_max=cfg.p_max)
    a, kva = model.prefill_mm(params, cfg, _img(), ids, ln, use_kernel=True)
    b, kvb = model.prefill_mm(params, cfg, _img(), ids, ln, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(kva), np.asarray(kvb), atol=1e-4, rtol=1e-4)


def test_verify_kernel_matches_ref(tparams):
    ids, ln = _prompt()
    _, kv = model.prefill_mm(tparams, CFG, _img(), ids, ln)
    toks = jnp.asarray([7, 8, 9, 10, 11, 12], jnp.int32)
    pos = CFG.n_visual + ln
    a, _ = model.extend(tparams, CFG, toks, pos, kv, use_kernel=True)
    b, _ = model.extend(tparams, CFG, toks, pos, kv, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_sliding_window_changes_gemsim_not_qwensim(gparams):
    """gemsim's odd layers are windowed: far-away context must be invisible
    to them.  Sanity-check the families actually differ structurally."""
    assert GCFG.layer_window(1) == 16
    assert GCFG.layer_window(0) is None
    assert CFG.layer_window(1) is None


# ---------------------------------------------------------------------------
# Draft scan semantics
# ---------------------------------------------------------------------------


def test_draft_scan_greedy_equals_stepwise(tparams):
    ids, ln = _prompt()
    last, kv = model.prefill_mm(tparams, CFG, _img(), ids, ln)
    start = int(jnp.argmax(last))
    pos = CFG.n_visual + ln
    toks, qlogits, _ = model.draft_scan(tparams, CFG, start, pos, kv, 0.0, 0)
    cur, p, k2 = start, pos, kv
    for i in range(5):
        lg, k2 = model.extend(tparams, CFG, jnp.asarray([cur], jnp.int32), p, k2)
        np.testing.assert_allclose(
            np.asarray(lg[0]), np.asarray(qlogits[i]), atol=1e-4, rtol=1e-4
        )
        cur = int(jnp.argmax(lg[0]))
        assert cur == int(toks[i])
        p += 1


def test_draft_scan_seed_determinism(tparams):
    ids, ln = _prompt()
    _, kv = model.prefill_mm(tparams, CFG, _img(), ids, ln)
    pos = CFG.n_visual + ln
    a, _, _ = model.draft_scan(tparams, CFG, 7, pos, kv, 1.0, 123)
    b, _, _ = model.draft_scan(tparams, CFG, 7, pos, kv, 1.0, 123)
    c, _, _ = model.draft_scan(tparams, CFG, 7, pos, kv, 1.0, 124)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c)) or True  # may collide


def test_draft_scan_temperature_zero_ignores_seed(tparams):
    ids, ln = _prompt()
    _, kv = model.prefill_mm(tparams, CFG, _img(), ids, ln)
    pos = CFG.n_visual + ln
    a, _, _ = model.draft_scan(tparams, CFG, 7, pos, kv, 0.0, 1)
    b, _, _ = model.draft_scan(tparams, CFG, 7, pos, kv, 0.0, 999)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# KV-cache staleness: the rollback-free property end to end
# ---------------------------------------------------------------------------


def test_kv_stale_tail_invariance(tparams):
    """Decoding after a (simulated) rejection must equal decoding on a
    fresh cache containing only the accepted prefix."""
    ids, ln = _prompt()
    _, kv = model.prefill_mm(tparams, CFG, _img(), ids, ln)
    pos = CFG.n_visual + ln
    # speculate 6 tokens (writes cache at pos..pos+5), then "reject" all
    spec = jnp.asarray([30, 31, 32, 33, 34, 35], jnp.int32)
    _, kv_dirty = model.extend(tparams, CFG, spec, pos, kv)
    # accept only token 30: next decode at pos+1 with the dirty cache...
    lg_dirty, _ = model.extend(tparams, CFG, jnp.asarray([30], jnp.int32), pos, kv_dirty)
    # ...must equal decode on the clean cache
    lg_clean, _ = model.extend(tparams, CFG, jnp.asarray([30], jnp.int32), pos, kv)
    np.testing.assert_allclose(
        np.asarray(lg_dirty), np.asarray(lg_clean), atol=1e-4, rtol=1e-4
    )


def test_prefill_matches_incremental_decode(tparams):
    """Prefill of [prompt] then decode of t must equal prefill of
    [prompt + t] -- cache write/read consistency."""
    ids, ln = _prompt("describe the image briefly .")
    last_a, kv = model.prefill_mm(tparams, CFG, _img(), ids, ln)
    nxt = int(jnp.argmax(last_a))
    lg_inc, _ = model.extend(
        tparams, CFG, jnp.asarray([nxt], jnp.int32), CFG.n_visual + ln, kv
    )
    ids2 = np.asarray(ids).copy()
    ids2[ln] = nxt
    last_b, _ = model.prefill_mm(tparams, CFG, _img(), jnp.asarray(ids2), ln + 1)
    np.testing.assert_allclose(
        np.asarray(lg_inc[0]), np.asarray(last_b), atol=1e-4, rtol=1e-4
    )


# ---------------------------------------------------------------------------
# Vision / projector / RoPE unit properties
# ---------------------------------------------------------------------------


def test_vision_encoder_is_image_sensitive(tparams):
    a = model.visual_embeds(tparams, CFG, _img(0))
    b = model.visual_embeds(tparams, CFG, _img(1))
    assert not np.allclose(np.asarray(a), np.asarray(b))
    assert a.shape == (CFG.n_visual, CFG.d_model)


def test_patchify_raster_order():
    img = jnp.arange(16 * 16 * 3, dtype=jnp.float32).reshape(16, 16, 3)
    p = nn.patchify(img, 4)
    assert p.shape == (16, 48)
    np.testing.assert_allclose(
        np.asarray(p[0]).reshape(4, 4, 3), np.asarray(img[:4, :4, :])
    )
    np.testing.assert_allclose(
        np.asarray(p[1]).reshape(4, 4, 3), np.asarray(img[:4, 4:8, :])
    )


def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 12)).astype(np.float32))
    pos = jnp.arange(8)
    y = nn.rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        atol=1e-4,
    )
    # relativity: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 12)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 12)).astype(np.float32))
    def dot(i, j):
        qi = nn.rope(q, jnp.asarray([i]))
        kj = nn.rope(k, jnp.asarray([j]))
        return float((qi[0, 0] * kj[0, 0]).sum())
    assert abs(dot(3, 1) - dot(10, 8)) < 1e-3


def test_loss_decreases_on_tiny_batch(tparams):
    """Three Adam steps on one batch must reduce the loss (gradient sanity)."""
    from compile import train

    data = sw.make_dataset(16, seed=0)
    batch = next(train.make_batches(data, 16, np.random.default_rng(0)))
    p = tparams
    opt = train.adam_init(p)
    losses = []
    for _ in range(3):
        loss, grads = jax.value_and_grad(
            lambda pp: model.next_token_loss(
                model.train_logits_mm(pp, CFG, batch["images"], batch["tokens"]),
                batch["tokens"],
                batch["mask"],
            )
        )(p)
        p, opt = train.adam_update(p, grads, opt, 1e-3)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_freeze_scale_zeroes_frozen_components(tparams):
    from compile import train

    grads = jax.tree.map(jnp.ones_like, tparams)
    out = train.freeze_scale(grads, {"vision": False, "proj": True, "lm": False})
    assert float(jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda x: jnp.abs(x).sum(), out["vision"])
    )) == 0.0
    assert float(jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda x: jnp.abs(x).sum(), out["proj"])
    )) > 0.0
    assert float(jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda x: jnp.abs(x).sum(), out["lm"])
    )) == 0.0
