"""Training pipeline units: batching/masking, selfdistill sampling, and a
miniature two-phase MASSV run that must improve drafter alignment."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, selfdistill, shapeworld as sw, train
from compile.config import MODELS


def test_assemble_sequence_layout():
    ex = sw.Example(
        image=np.zeros((16, 16, 3), np.float32),
        prompt_ids=sw.encode("describe the image briefly ."),
        answer_ids=sw.encode("the image shows a red circle in the top left ."),
        task="coco",
    )
    toks, mask, plen = train.assemble_sequence(ex)
    assert toks[0] == sw.BOS_ID
    assert toks[plen - 1] == sw.SEP_ID
    # supervision exactly on answer + <eos>
    n_answer = len(ex.answer_ids) + 1
    assert mask.sum() == n_answer
    assert mask[plen] == 1.0 and mask[plen - 1] == 0.0
    eos_pos = plen + len(ex.answer_ids)
    assert toks[eos_pos] == sw.EOS_ID
    assert (toks[eos_pos + 1 :] == sw.PAD_ID).all()


def test_make_batches_shapes_and_supervise_all():
    data = sw.make_dataset(40, seed=0)
    rng = np.random.default_rng(0)
    b = next(train.make_batches(data, 8, rng))
    assert b["tokens"].shape == (8, train.S_TXT)
    assert b["images"].shape == (8, 16, 16, 3)
    b2 = next(train.make_batches(data, 8, rng, supervise_all=True, with_images=False))
    assert "images" not in b2
    # supervise_all masks every non-pad token
    toks = np.asarray(b2["tokens"])
    mask = np.asarray(b2["mask"])
    assert ((toks != sw.PAD_ID).astype(np.float32) == mask).all()


def test_batches_cover_dataset_once_per_epoch():
    data = sw.make_dataset(32, seed=1)
    rng = np.random.default_rng(0)
    n = sum(b["tokens"].shape[0] for b in train.make_batches(data, 8, rng))
    assert n == 32


@settings(max_examples=20, deadline=None)
@given(temp=st.floats(0.1, 2.0), top_p=st.floats(0.1, 1.0))
def test_top_p_sample_in_support(temp, top_p):
    rng = np.random.default_rng(0)
    logits = np.asarray(rng.normal(size=32), np.float32)
    for _ in range(10):
        t = selfdistill._top_p_sample(logits, temp, top_p, rng)
        assert 0 <= t < 32


def test_top_p_sample_greedy_at_zero_temperature():
    rng = np.random.default_rng(0)
    logits = np.asarray([0.1, 3.0, -1.0], np.float32)
    assert selfdistill._top_p_sample(logits, 0.0, 0.9, rng) == 1


def test_top_p_restricts_support():
    rng = np.random.default_rng(0)
    # token 0 holds ~88% of the mass; top_p=0.5 must always pick it
    logits = np.asarray([4.0, 2.0, 0.0, -2.0], np.float32)
    for _ in range(50):
        assert selfdistill._top_p_sample(logits, 1.0, 0.5, rng) == 0


def test_adam_converges_on_quadratic():
    import jax
    import jax.numpy as jnp

    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = train.adam_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = train.adam_update(params, g, opt, 0.05)
    assert float(loss(params)) < 1e-3


@pytest.mark.slow
def test_miniature_two_phase_pipeline_improves_alignment():
    """A tiny end-to-end MASSV run: target trained briefly, drafter adapted
    with phase-1 + SDViT; SDViT must reduce eval loss on target-generated
    data vs the phase-1-only drafter (the paper's core claim in miniature)."""
    import jax
    import jax.numpy as jnp

    tcfg, dcfg = MODELS["qwensim-L"], MODELS["qwensim-S"]
    data = sw.make_dataset(96, seed=5, style_mix=True)
    target = model.init_target_params(tcfg, 0)
    target = train.train_phase(
        target, tcfg, data, epochs=4, lr=3e-3, multimodal=True, seed=0,
        phase_name="t", curves=None,
    )
    slm = model.init_target_params(dcfg, 1)
    drafter = model.init_drafter_params(dcfg, target["vision"], slm["lm"], 2)
    drafter = train.train_phase(
        drafter, dcfg, sw.pretrain_pairs(64, 6), epochs=2, lr=1e-3,
        multimodal=True, trainable={"vision": False, "proj": True, "lm": False},
        seed=1, phase_name="p1", curves=None,
    )
    sdd = selfdistill.distill_dataset(
        target, tcfg, data[:48], temperatures=(0.7,), top_p=0.9, seed=7,
        batch_size=48,
    )
    massv = train.train_phase(
        dict(drafter), dcfg, sdd, epochs=3, lr=5e-4, multimodal=True,
        trainable={"vision": False, "proj": True, "lm": True},
        seed=2, phase_name="p2", curves=None,
    )

    # eval: NLL of target-generated continuations under each drafter
    eval_sdd = selfdistill.distill_dataset(
        target, tcfg, data[48:72], temperatures=(0.7,), top_p=0.9, seed=8,
        batch_size=24,
    )
    rng = np.random.default_rng(0)
    batch = next(train.make_batches(eval_sdd, 24, rng))

    def nll(params):
        logits = model.train_logits_mm(params, dcfg, batch["images"], batch["tokens"])
        return float(model.next_token_loss(logits, batch["tokens"], batch["mask"]))

    before, after = nll(drafter), nll(massv)
    assert after < before, f"SDViT did not improve alignment: {before} -> {after}"
