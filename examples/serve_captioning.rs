//! END-TO-END SERVING DRIVER (the mandated full-system example).
//!
//! Boots the complete stack -- PJRT runtime, coordinator (scheduler +
//! router + worker pool), TCP server -- then drives it with an open-loop
//! Poisson captioning workload over real sockets, and reports latency /
//! throughput / acceptance statistics.  Proves all three layers compose:
//! Pallas kernel (L1, inside the AOT HLO) -> JAX models (L2, baked
//! artifacts) -> Rust serving (L3, this process).  Recorded in
//! EXPERIMENTS.md section End-to-end.
//!
//!     cargo run --release --example serve_captioning [-- --rate 4 --n 40]

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use massv::coordinator::{Engine, EngineConfig};
use massv::server::{Client, Server};
use massv::stats;
use massv::util::cli::Args;
use massv::util::json::Json;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1), &[]);
    let artifacts = massv::util::artifacts_dir();
    let n_requests = args.get_usize("n", 40);
    let rate = args.get_f64("rate", 4.0); // req/s open loop
    let workers = args.get_usize("workers", 4);

    println!("== MASSV end-to-end serving demo ==");
    println!("booting engine ({workers} workers) + TCP server ...");
    let engine = Arc::new(Engine::start(
        &artifacts,
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers,
            queue_capacity: 512,
            ..EngineConfig::default()
        },
    )?);
    let items = workload::load_task(
        &artifacts,
        "coco",
        &engine.tokenizer,
        engine.models.manifest.p_max,
    )?;

    let server = Server::new(engine.clone());
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let server_thread = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap().to_string();
    println!("server listening on {addr}");

    // warm the executable cache with one request so timing is honest
    let mut warm = Client::connect(&addr)?;
    let _ = warm.call(&gen_req(&items[0], 0))?;

    // ---- open-loop Poisson load over real sockets -------------------------
    let schedule = workload::poisson_schedule(n_requests, rate, items.len(), 42);
    println!(
        "driving {n_requests} captioning requests at ~{rate}/s (open loop, Poisson) ...\n"
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (i, arr) in schedule.iter().enumerate() {
        let wait = Duration::from_secs_f64(arr.at) - t0.elapsed().min(Duration::from_secs_f64(arr.at));
        std::thread::sleep(wait);
        let addr = addr.clone();
        let item = items[arr.item].clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(f64, usize, f64)> {
            let issued = Instant::now();
            let mut c = Client::connect(&addr)?;
            let resp = c.call(&gen_req(&item, i as u64))?;
            let e2e_ms = issued.elapsed().as_secs_f64() * 1000.0;
            anyhow::ensure!(resp.get("error").is_none(), "{resp:?}");
            let tokens = resp.get("tokens").unwrap().to_i32_vec()?.len();
            let mal = resp.get("mal").unwrap().as_f64()?;
            Ok((e2e_ms, tokens, mal))
        }));
    }

    let mut lat = Vec::new();
    let mut mals = Vec::new();
    let mut tokens = 0usize;
    for h in handles {
        let (ms, toks, mal) = h.join().unwrap()?;
        lat.push(ms);
        mals.push(mal);
        tokens += toks;
    }
    let wall = t0.elapsed().as_secs_f64();

    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("== results ==");
    println!("wall time          {wall:.2} s");
    println!("throughput         {:.2} req/s, {:.0} tok/s", n_requests as f64 / wall, tokens as f64 / wall);
    println!("latency (client)   p50 {:.0} ms  p95 {:.0} ms  max {:.0} ms",
        lat[lat.len() / 2], lat[(lat.len() as f64 * 0.95) as usize], lat[lat.len() - 1]);
    println!("mean accepted len  {:.2} (per-request mean {:.2})",
        engine.metrics.overall_mal(), stats::mean(&mals));
    println!("server metrics     completed={} rejected={} verify_calls={}",
        engine.metrics.requests_completed.get(),
        engine.metrics.requests_rejected.get(),
        engine.metrics.verify_calls.get());

    stop.store(true, Ordering::Relaxed);
    server_thread.join().unwrap();
    Ok(())
}

fn gen_req(item: &workload::EvalItem, seed: u64) -> Json {
    Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str(item.prompt.clone())),
        ("image", Json::arr_f32(&item.image)),
        ("task", Json::str("coco")),
        ("mode", Json::str("massv")),
        ("priority", Json::str("interactive")),
        ("seed", Json::num(seed as f64)),
    ])
}
