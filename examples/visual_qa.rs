//! Visual QA walkthrough: run GQA-style reasoning prompts through MASSV
//! speculative decoding and show, token by token, where the drafter's
//! speculation succeeds (function words, grammar) and where the target
//! must intervene (visually grounded tokens) -- the paper's section 5.2
//! mechanism made visible.
//!
//!     cargo run --release --example visual_qa [-- --n 5 --temperature 0]

use massv::models::ModelSet;
use massv::spec::{GenConfig, SpecDecoder};
use massv::tokenizer::Tokenizer;
use massv::util::cli::Args;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1), &[]);
    let artifacts = massv::util::artifacts_dir();
    let n = args.get_usize("n", 5);
    let temperature = args.get_f64("temperature", 0.0) as f32;

    let models = ModelSet::load(&artifacts)?;
    let tok = Tokenizer::load(&artifacts)?;
    let items = workload::load_task(&artifacts, "gqa", &tok, models.manifest.p_max)?;

    let target = models.target("qwensim-L")?;
    let drafter = models.drafter_for("qwensim-L", "massv")?;
    let dec = SpecDecoder::new(target, drafter);

    let mut total_iters = 0usize;
    let mut total_emitted = 0usize;
    for (i, it) in items.iter().take(n).enumerate() {
        let cfg = GenConfig { temperature, top_p: 1.0, max_new: 48, seed: i as u64, tree: None };
        let stats = dec.generate(&it.image, &it.prompt_ids, it.prompt_len, &cfg)?;
        println!("── question {} {}", i + 1, "─".repeat(48));
        println!("Q: {}", it.prompt);
        println!("ref: {}", it.reference);
        println!(
            "A: {}",
            tok.decode(
                &stats
                    .tokens
                    .iter()
                    .filter(|&&t| t != models.manifest.eos_id)
                    .map(|&t| t as u32)
                    .collect::<Vec<_>>()
            )
        );
        // acceptance summary: how much speculation survived (GenStats folds
        // per-iteration counts into streaming summaries)
        println!(
            "speculation (gamma={}): {} accepted over {} verifies, best iter emitted {}",
            models.manifest.gamma,
            stats.accepted_draft,
            stats.verify_calls,
            stats.emitted_max
        );
        println!("tau = {:.2} over {} verifies\n", stats.mal(), stats.verify_calls);
        total_iters += stats.verify_calls;
        total_emitted += stats.emitted_sum;
    }
    println!(
        "pooled tau over {n} questions: {:.2}",
        total_emitted as f64 / total_iters.max(1) as f64
    );
    Ok(())
}
