//! Quickstart: load the engine, caption one image with MASSV speculative
//! decoding, and compare against plain target decoding.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` to have produced ./artifacts.

use massv::coordinator::{DecodeMode, Engine, EngineConfig, Request};
use massv::workload;

fn main() -> anyhow::Result<()> {
    let artifacts = massv::util::artifacts_dir();
    let engine = Engine::start(
        &artifacts,
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers: 1,
            queue_capacity: 8,
            ..EngineConfig::default()
        },
    )?;

    // pick a captioning prompt + image from the fixed eval set
    let items = workload::load_task(
        &artifacts,
        "coco",
        &engine.tokenizer,
        engine.models.manifest.p_max,
    )?;
    let item = &items[0];
    println!("prompt:    {}", item.prompt);
    println!("reference: {}", item.reference);

    // warm the executable cache (HLO parse + compile costs seconds on
    // first use and would otherwise be billed to the first request)
    let mut warm = Request::simple(engine.next_id(), &item.prompt, item.image.clone());
    warm.gen.max_new = 2;
    let _ = engine.run(warm);
    let mut warm = Request::simple(engine.next_id(), &item.prompt, item.image.clone());
    warm.mode = DecodeMode::TargetOnly;
    warm.gen.max_new = 2;
    let _ = engine.run(warm);

    // --- MASSV speculative decoding --------------------------------------
    let mut req = Request::simple(engine.next_id(), &item.prompt, item.image.clone());
    req.task = "coco".into();
    let spec = engine.run(req);
    println!("\n[MASSV speculative]");
    println!("output:  {}", spec.text);
    println!(
        "mal {:.2} | {} verify calls | {} draft tokens accepted | {:.1} ms",
        spec.mal, spec.verify_calls, spec.accepted_draft, spec.latency_ms
    );

    // --- plain target decoding (the 1.00x reference) ----------------------
    let mut req = Request::simple(engine.next_id(), &item.prompt, item.image.clone());
    req.task = "coco".into();
    req.mode = DecodeMode::TargetOnly;
    let base = engine.run(req);
    println!("\n[target only]");
    println!("output:  {}", base.text);
    println!("{} target forwards | {:.1} ms", base.verify_calls, base.latency_ms);

    // greedy speculation is lossless: outputs must match exactly
    assert_eq!(spec.tokens, base.tokens, "losslessness violated!");
    println!(
        "\noutputs identical (lossless); wallclock speedup {:.2}x",
        base.latency_ms / spec.latency_ms.max(1e-9)
    );
    engine.shutdown();
    Ok(())
}
