//! Drafting-strategy ablation playground: compare all drafter variants
//! (text-only baseline, MASSV w/o SDViT, MASSV, MASSV-in-text-only-mode)
//! on one task, at both temperatures -- a compact interactive version of
//! Tables 2 and 3.
//!
//!     cargo run --release --example ablation_drafting [-- --task coco --n 10]

use massv::eval::{pooled_mal, run_spec};
use massv::models::ModelSet;
use massv::spec::{AdaptiveConfig, AdaptiveDecoder, GenConfig, SpecDecoder};
use massv::tokenizer::Tokenizer;
use massv::util::cli::Args;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1), &[]);
    let artifacts = massv::util::artifacts_dir();
    let task = args.get_or("task", "coco").to_string();
    let n = args.get_usize("n", 10);
    let target = args.get_or("target", "qwensim-L").to_string();

    let models = ModelSet::load(&artifacts)?;
    let tok = Tokenizer::load(&artifacts)?;
    let mut items = workload::load_task(&artifacts, &task, &tok, models.manifest.p_max)?;
    items.truncate(n);

    println!("drafting ablation on {task} ({n} prompts, target {target})\n");
    println!("{:<34} {:>8} {:>8}", "strategy", "tau@T=0", "tau@T=1");
    for (label, variant, text_only) in [
        ("text-only baseline (Gagrani+24)", "baseline", false),
        ("MASSV w/o SDViT", "massv_wo_sdvit", false),
        ("MASSV (full)", "massv", false),
        ("MASSV drafter, visual discarded", "massv", true),
    ] {
        let mut mals = Vec::new();
        for t in [0.0f32, 1.0] {
            let stats = run_spec(&models, &target, variant, &items, t, text_only, 11)?;
            mals.push(pooled_mal(&stats));
        }
        println!("{label:<34} {:>8.2} {:>8.2}", mals[0], mals[1]);
    }
    // extension: adaptive speculation controller (spec::adaptive) -- same
    // outputs at T=0, bounded worst case when alignment is poor
    {
        let t = models.target(&target)?;
        let d = models.drafter_for(&target, "massv")?;
        let dec = AdaptiveDecoder::new(SpecDecoder::new(t, d), AdaptiveConfig::default());
        let mut mals = Vec::new();
        let mut fallbacks = 0usize;
        for temp in [0.0f32, 1.0] {
            let mut emitted = 0usize;
            let mut iters = 0usize;
            for (i, it) in items.iter().enumerate() {
                let cfg =
                    GenConfig { temperature: temp, top_p: 1.0, max_new: 48, seed: i as u64, tree: None };
                let s = dec.generate(&it.image, &it.prompt_ids, it.prompt_len, &cfg)?;
                emitted += s.emitted_sum;
                iters += s.verify_calls;
                fallbacks += usize::from(s.fallback_at.is_some());
            }
            mals.push(emitted as f64 / iters.max(1) as f64);
        }
        println!("{:<34} {:>8.2} {:>8.2}   ({} fallbacks)",
                 "MASSV + adaptive controller", mals[0], mals[1], fallbacks);
    }
    println!(
        "\nExpected shape (paper sections 5.1-5.2): MASSV > w/o SDViT and > baseline;\n\
         discarding visual tokens costs acceptance on visually grounded tasks."
    );
    Ok(())
}
