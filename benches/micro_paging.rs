//! Paged-KV microbenchmark (section Perf, layer 3): memory and fork cost
//! of the block pool (`massv::kv`, docs/paged_kv.md) against the
//! deep-copy baseline it replaced.
//!
//! Two axes, matching the reason the pool exists:
//!
//!   * **bytes per concurrent session** -- N sessions forked from one warm
//!     prefix.  Deep copy charges a full KV per session; the pool charges
//!     a block table (refcount bumps) until a fork diverges, and then only
//!     the diverged blocks.
//!   * **fork latency** -- `PagedKv::clone()` (O(table) refcount bumps)
//!     vs cloning the whole literal.
//!
//! Pure in-process pool work, no engine and no PJRT: the numbers isolate
//! the data structure.  Besides the human-readable report, the run writes
//! machine-readable `target/paper/BENCH_paging.json`; CI smoke-runs this
//! bench and archives the JSON.  A checked-in baseline lives at
//! `benches/baselines/BENCH_paging.json`.
//!
//! The run FAILS (hard assert) if a fork's incremental pool cost stops
//! being small next to a full sequence KV -- the pool's headline claim.
//!
//!     cargo bench --bench micro_paging [-- --quick]

mod harness;

use harness::{measure, summarize, BenchReport};
use massv::kv::{KvPool, KvPoolConfig};
use massv::util::json::Json;

/// One sequence's KV: 16Ki f32 words (64 KiB) split into 16 pool blocks.
const SEQ_WORDS: usize = 16 * 1024;
const BLOCK_WORDS: usize = 1024;

fn median(micros: &[f64]) -> f64 {
    let mut v = micros.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MASSV_BENCH_QUICK").ok().as_deref() == Some("1");
    let (forks, warmup, iters) = if quick { (16, 5, 60) } else { (64, 20, 400) };
    let seq_bytes = SEQ_WORDS * 4;

    let mut report = BenchReport::new("micro_paging");
    report.line(format!(
        "paged KV pool: seq {SEQ_WORDS} words ({seq_bytes} B), block {BLOCK_WORDS} words, \
         {forks} concurrent forks"
    ));

    let kv: Vec<f32> = (0..SEQ_WORDS).map(|i| (i % 251) as f32 * 0.5).collect();
    let lit = xla::Literal::vec1(&kv);

    // ---- bytes per concurrent session --------------------------------
    let pool = KvPool::new(KvPoolConfig {
        block_words: BLOCK_WORDS,
        budget_bytes: usize::MAX,
    });
    let base = pool.store(&lit);
    let bytes_base = pool.bytes_used();

    // fork: every session shares every block -- zero incremental bytes
    let mut sessions: Vec<_> = (0..forks).map(|_| base.clone()).collect();
    let shared_per_fork = (pool.bytes_used() - bytes_base) as f64 / forks as f64;

    // diverge: each session rewrites its final block (one decode step's
    // worth of drift) -- copy-on-write copies ONLY that block
    let mut diverged = kv.clone();
    for (i, s) in sessions.iter_mut().enumerate() {
        diverged[SEQ_WORDS - 1] = 1000.0 + i as f32;
        s.write(&xla::Literal::vec1(&diverged));
    }
    let diverged_per_fork = (pool.bytes_used() - bytes_base) as f64 / forks as f64;
    let deep_per_fork = seq_bytes as f64; // deep copy charges the full KV

    report.line(format!(
        "bytes/session  deep-copy {deep_per_fork:>9.0} B   paged(shared) {shared_per_fork:>6.0} B   \
         paged(diverged) {diverged_per_fork:>6.0} B   sharing {:.1}x",
        deep_per_fork / diverged_per_fork.max(1.0)
    ));

    // every fork still reads back its own bit-exact content
    let check = sessions[forks / 2].to_literal().to_vec::<f32>().unwrap();
    assert_eq!(check[SEQ_WORDS - 1], 1000.0 + (forks / 2) as f32);
    assert_eq!(&check[..SEQ_WORDS - 1], &kv[..SEQ_WORDS - 1]);

    // ---- fork latency ------------------------------------------------
    let paged_us = measure(warmup, iters, || {
        let f = base.clone(); // refcount bump per block + drop decref
        assert_eq!(f.blocks(), SEQ_WORDS / BLOCK_WORDS);
    });
    let deep_us = measure(warmup, iters, || {
        let f = lit.clone(); // full payload copy + drop free
        assert_eq!(f.element_count(), SEQ_WORDS);
    });
    report.line(summarize("fork latency: paged clone (block table)", &paged_us));
    report.line(summarize("fork latency: deep copy (whole literal)", &deep_us));

    // ---- swap round-trip (preemption path) ---------------------------
    let swap_us = measure(warmup, iters, || {
        let mut f = base.clone();
        f.swap_out();
        f.swap_in();
        assert!(!f.is_swapped());
    });
    report.line(summarize("preemption: swap_out + swap_in round-trip", &swap_us));

    drop(sessions);
    drop(base);
    assert_eq!(pool.bytes_used(), 0, "dropping every handle must free the pool");

    let (paged_med, deep_med, swap_med) = (median(&paged_us), median(&deep_us), median(&swap_us));
    let json = Json::obj(vec![
        ("bench", Json::str("micro_paging")),
        ("seq_words", Json::num(SEQ_WORDS as f64)),
        ("block_words", Json::num(BLOCK_WORDS as f64)),
        ("forks", Json::num(forks as f64)),
        ("deep_bytes_per_fork", Json::num(deep_per_fork)),
        ("paged_bytes_per_fork_shared", Json::num(shared_per_fork)),
        ("paged_bytes_per_fork_diverged", Json::num(diverged_per_fork)),
        ("sharing_factor", Json::num(deep_per_fork / diverged_per_fork.max(1.0))),
        ("fork_us_paged_median", Json::num(paged_med)),
        ("fork_us_deep_median", Json::num(deep_med)),
        ("swap_roundtrip_us_median", Json::num(swap_med)),
    ]);
    std::fs::create_dir_all("target/paper").ok();
    std::fs::write("target/paper/BENCH_paging.json", format!("{}\n", json.to_string()))?;
    report.line("[json saved to target/paper/BENCH_paging.json]");
    report.finish();

    // Headline claims, enforced: a shared fork costs literally nothing,
    // and a diverged fork costs one block -- far below a sequence's KV.
    assert_eq!(shared_per_fork, 0.0, "undiverged forks must share every block");
    assert!(
        diverged_per_fork * 8.0 <= seq_bytes as f64,
        "a diverged fork's incremental bytes ({diverged_per_fork:.0} B) must stay \
         well below one sequence's KV ({seq_bytes} B)"
    );
    Ok(())
}
