//! Figure 1: end-to-end wallclock speedups when drafting for the primary
//! target (qwensim-L / "Qwen2.5-VL 7B" analog) at T=0, gamma=5, per task
//! category plus overall, for BASELINE text-only drafting vs MASSV.
//! Rendered as an ASCII bar chart + the underlying numbers.
//!
//!     cargo bench --bench fig1_speedup [-- --quick]

mod harness;

use harness::{artifacts_or_exit, items_per_cell, BenchReport};
use massv::eval::{eval_cell, tables};
use massv::models::ModelSet;
use massv::tokenizer::Tokenizer;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_or_exit("fig1_speedup");
    let n = items_per_cell();
    let models = ModelSet::load(&dir)?;
    let tok = Tokenizer::load(&dir)?;
    let mut report = BenchReport::new("fig1_speedup");
    let tasks = workload::load_all_tasks(&dir, &tok, models.manifest.p_max)?;
    let target = "qwensim-L";

    report.line(format!(
        "Figure 1 reproduction: end-to-end wallclock speedup vs plain target decoding\n\
         target {target}, T=0, gamma={}, {n} items/task\n",
        models.manifest.gamma
    ));

    let mut bars = Vec::new();
    for variant in ["baseline", "massv"] {
        let mut cells = Vec::new();
        for (task, items) in &tasks {
            let items = &items[..n.min(items.len())];
            let c = eval_cell(&models, target, variant, task, items, 0.0, false, true)?;
            bars.push((format!("{variant}/{task}"), c.wall_speedup));
            cells.push(c);
        }
        bars.push((
            format!("{variant}/OVERALL"),
            tables::overall_wall_speedup(&cells),
        ));
    }
    report.line(tables::bar_chart(
        "end-to-end speedup over target-only decoding (x)",
        &bars,
        "x",
        48,
    ));
    report.finish();
    Ok(())
}
