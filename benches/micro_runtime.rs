//! Runtime microbenchmarks (section Perf, layer 3): per-entry-point PJRT
//! call latency, the KV literal round-trip cost, and the call-count
//! economics of the fused draft loop vs step-wise drafting.
//!
//!     cargo bench --bench micro_runtime

mod harness;

use harness::{artifacts_or_exit, measure, summarize, BenchReport};
use massv::models::ModelSet;
use massv::runtime::Tensor;
use massv::tokenizer::Tokenizer;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_or_exit("micro_runtime");
    let models = ModelSet::load(&dir)?;
    let tok = Tokenizer::load(&dir)?;
    let items = workload::load_task(&dir, "coco", &tok, models.manifest.p_max)?;
    let it = &items[0];
    let mut report = BenchReport::new("micro_runtime");
    let gamma = models.manifest.gamma;

    report.line("runtime microbenchmarks (PJRT CPU, batch-1 executables)\n");

    for tname in ["qwensim-L", "qwensim-XL"] {
        let target = models.target(tname)?;
        // prefill
        let us = measure(3, 20, || {
            let _ = target.prefill_mm(&it.image, &it.prompt_ids, it.prompt_len).unwrap();
        });
        report.line(summarize(&format!("{tname}::prefill_mm"), &us));

        // verify + decode on a live state
        let (_, mut st) = target.prefill_mm(&it.image, &it.prompt_ids, it.prompt_len)?;
        let toks: Vec<i32> = (0..=gamma as i32).collect();
        let us = measure(3, 50, || {
            let _ = target.verify(&mut st, &toks).unwrap();
        });
        report.line(summarize(&format!("{tname}::verify(gamma+1)"), &us));

        let (_, mut st) = target.prefill_mm(&it.image, &it.prompt_ids, it.prompt_len)?;
        let us = measure(3, 50, || {
            st.pos -= 1;
            let _ = target.decode(&mut st, 7).unwrap();
        });
        report.line(summarize(&format!("{tname}::decode(1)"), &us));
    }

    let drafter = models.drafter("qwensim-S", "massv")?;
    let mut ds = drafter.prefill(Some(&it.image), &it.prompt_ids, it.prompt_len, false)?;
    let us = measure(3, 50, || {
        let _ = drafter.draft(&mut ds, 7, 0.0, 1).unwrap();
    });
    report.line(summarize("qwensim-S::draft (fused, gamma tokens)", &us));

    let mut ds = drafter.prefill(Some(&it.image), &it.prompt_ids, it.prompt_len, false)?;
    let us = measure(3, 50, || {
        ds.pos -= 1;
        let _ = drafter.decode(&mut ds, 7).unwrap();
    });
    report.line(summarize("qwensim-S::decode (one step)", &us));
    report.line(format!(
        "\n-> step-wise drafting would cost gamma={gamma} decode calls + sampling \
         round-trips per SD iteration;\n   the fused draft loop collapses that \
         into ONE call (see EXPERIMENTS.md section Perf).\n"
    ));

    // KV literal round-trip cost (the host<->device copy we pay per call)
    let target = models.target("qwensim-L")?;
    let (_, st) = target.prefill_mm(&it.image, &it.prompt_ids, it.prompt_len)?;
    let kv_lit = st.kv.literal();
    let kv = Tensor::from_literal(&kv_lit)?;
    report.line(format!(
        "KV cache: {:?} = {} f32 = {:.2} MiB",
        kv.dims,
        kv.numel(),
        kv.numel() as f64 * 4.0 / (1 << 20) as f64
    ));
    let us = measure(3, 50, || {
        let t = Tensor::from_literal(&kv_lit).unwrap();
        let _ = t.to_literal().unwrap();
    });
    report.line(summarize("kv literal host round-trip (down+up)", &us));

    // ---- interpret-Pallas vs fused-jnp lowering (the L1 CPU ablation) ----
    let raw = massv::util::json::parse(&massv::util::read_file(&format!(
        "{dir}/manifest.json"
    ))?)?;
    if let Some(recs) = raw.get("kernel_validation") {
        if let Some(rec) = recs.as_arr()?.iter().find(|r| {
            r.get("name").and_then(|n| n.as_str().ok()) == Some("qwensim-L")
        }) {
            let file = rec
                .req("entries")?
                .req("verify")?
                .req("file")?
                .as_str()?
                .to_string();
            let kexec = models.rt.load_exec(&format!("{dir}/{file}"), "kernel_verify")?;
            let target = models.target("qwensim-L")?;
            let (_, st) = target.prefill_mm(&it.image, &it.prompt_ids, it.prompt_len)?;
            let toks: Vec<i32> = (0..=gamma as i32).collect();
            let args = [
                massv::runtime::lit_i32(&toks, &[gamma + 1])?,
                massv::runtime::scalar_i32(st.pos),
                st.kv.literal(),
            ];
            let us = measure(2, 10, || {
                let _ = kexec.call(&args).unwrap();
            });
            report.line(String::new());
            report.line(summarize("qwensim-L::verify (interpret-Pallas lowering)", &us));
            report.line(
                "-> compare with qwensim-L::verify above (fused-jnp serving lowering); \
                 this gap is why CPU serving uses the fused artifacts \
                 (aot.py SERVE_KERNEL) while the kernel remains the TPU story."
                    .to_string(),
            );
        }
    }

    // per-exec mean latencies accumulated during this run
    report.line("\nper-executable means (from runtime counters):");
    let mut stats = models.exec_stats();
    stats.sort_by(|a, b| a.0.cmp(&b.0));
    for (name, calls, mean_us) in stats {
        report.line(format!("  {name:<42} calls={calls:<5} mean {mean_us:>9.1} us"));
    }
    report.finish();
    Ok(())
}
