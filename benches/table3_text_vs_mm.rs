//! Table 3: text-only vs multimodal drafting with the SAME MASSV drafter.
//! Text-only mode discards the visual tokens (the drafter's language
//! backbone alone), mirroring the paper's section 5.2 ablation.  Expected
//! shape: multimodal > text-only on the overall benchmark, with the gap
//! concentrated on visually grounded tokens.
//!
//!     cargo bench --bench table3_text_vs_mm [-- --quick]

mod harness;

use harness::{artifacts_or_exit, items_per_cell, BenchReport};
use massv::eval::{eval_cell, tables, CellResult};
use massv::models::ModelSet;
use massv::tokenizer::Tokenizer;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_or_exit("table3_text_vs_mm");
    let n = items_per_cell();
    let models = ModelSet::load(&dir)?;
    let tok = Tokenizer::load(&dir)?;
    let mut report = BenchReport::new("table3_text_vs_mm");
    let tasks = workload::load_all_tasks(&dir, &tok, models.manifest.p_max)?;

    report.line(format!(
        "Table 3 reproduction: text-only vs multimodal drafting (MASSV drafter, T=0, {n} items/task)\n"
    ));

    for target in ["qwensim-L", "gemsim-L"] {
        let mut rows = Vec::new();
        for (label, text_only) in [("TEXT-ONLY", true), ("MULTIMODAL", false)] {
            let mut cells: Vec<CellResult> = Vec::new();
            let mut per_task = Vec::new();
            for (task, items) in &tasks {
                let items = &items[..n.min(items.len())];
                let c = eval_cell(&models, target, "massv", task, items, 0.0, text_only, false)?;
                per_task.push(format!("{:.2}", c.mal));
                cells.push(c);
            }
            per_task.push(format!("{:.2}", tables::overall_mal(&cells)));
            rows.push((label.to_string(), per_task));
        }
        let analog = &models.manifest.target(target)?.paper_analog;
        let t = tables::TableBlock {
            title: format!("{target} ({analog}) — tau by drafting mode"),
            columns: vec![
                "instruct".into(),
                "wild".into(),
                "gqa".into(),
                "coco".into(),
                "OVERALL".into(),
            ],
            rows,
        };
        report.line(t.render());
    }
    report.finish();
    Ok(())
}
