//! Sampler microbenchmarks (section Perf): the host-side acceptance math
//! must be negligible next to a PJRT call (hundreds of microseconds).
//! No artifacts needed.
//!
//!     cargo bench --bench micro_sampler

mod harness;

use harness::{measure, summarize, BenchReport};
use massv::runtime::Tensor;
use massv::spec::{accept_stochastic, sampler, Scratch};
use massv::util::rng::Rng;

fn main() {
    let mut report = BenchReport::new("micro_sampler");
    let v = 120; // shape-world vocab size
    let mut rng = Rng::seeded(1);
    let logits: Vec<f32> = (0..v).map(|_| rng.f32() * 8.0 - 4.0).collect();

    report.line(format!("sampler microbenchmarks (vocab={v})\n"));

    let mut probs = Vec::new();
    let us = measure(100, 2000, || {
        sampler::softmax_t(&logits, 1.0, &mut probs);
    });
    report.line(summarize("softmax_t", &us));

    let us = measure(100, 2000, || {
        let _ = sampler::argmax(&logits);
    });
    report.line(summarize("argmax", &us));

    sampler::softmax_t(&logits, 1.0, &mut probs);
    let mut perm = Vec::new();
    let us = measure(100, 2000, || {
        let mut p = probs.clone();
        sampler::top_p_filter(&mut p, 0.9, &mut perm);
    });
    report.line(summarize("top_p_filter (incl. clone)", &us));

    let mut out = Vec::new();
    let q: Vec<f32> = {
        let mut q = probs.clone();
        q.rotate_right(3);
        q
    };
    let us = measure(100, 2000, || {
        sampler::residual(&probs, &q, &mut out);
    });
    report.line(summarize("residual distribution", &us));

    // a full gamma=5 stochastic acceptance pass
    let gamma = 5;
    let qlogits = Tensor::new(
        (0..gamma * v).map(|i| ((i * 37) % 97) as f32 * 0.05).collect(),
        vec![gamma, v],
    )
    .unwrap();
    let plogits = Tensor::new(
        (0..(gamma + 1) * v).map(|i| ((i * 53) % 89) as f32 * 0.05).collect(),
        vec![gamma + 1, v],
    )
    .unwrap();
    let draft = vec![3i32, 14, 15, 9, 26];
    let mut scratch = Scratch::default();
    let us = measure(100, 2000, || {
        let _ = accept_stochastic(&draft, &qlogits, &plogits, 1.0, 1.0, &mut rng, &mut scratch);
    });
    report.line(summarize("accept_stochastic (full gamma window)", &us));
    report.line("\n-> all host-side costs are O(microseconds); the PJRT call dominates.".to_string());
    report.finish();
}
