//! Table 1: mean accepted lengths (tau) and speedups across model
//! families, tasks, and temperatures (T in {0, 1}) with gamma = 5.
//!
//! Baseline = text-only drafting (Gagrani et al.); MASSV = this paper.
//! Like the paper, speedups are normalized to the baseline drafter's MAL
//! via measured wallclock; the XL rows are the section-4.2 generalization
//! experiment (drafter aligned to the L target, serving the XL target).
//!
//!     cargo bench --bench table1 [-- --quick]

mod harness;


use harness::{artifacts_or_exit, items_per_cell, BenchReport};
use massv::eval::{eval_cell, tables, CellResult};
use massv::models::ModelSet;
use massv::tokenizer::Tokenizer;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_or_exit("table1");
    let n = items_per_cell();
    let models = ModelSet::load(&dir)?;
    let tok = Tokenizer::load(&dir)?;
    let mut report = BenchReport::new("table1");

    let tasks = workload::load_all_tasks(&dir, &tok, models.manifest.p_max)?;
    let targets = ["qwensim-L", "qwensim-XL", "gemsim-L", "gemsim-XL"];

    report.line(format!(
        "Table 1 reproduction: tau and speedup, gamma={}, {} items/cell",
        models.manifest.gamma, n
    ));
    report.line("(speedup = measured wallclock per token vs non-speculative target decode)\n");

    for temperature in [0.0f32, 1.0] {
        report.line(format!("---- TEMPERATURE = {temperature} ----"));
        for target in targets {
            let mut rows: Vec<(String, Vec<String>)> = Vec::new();
            let mut overall: Vec<(String, Vec<CellResult>)> = Vec::new();
            for variant in ["baseline", "massv"] {
                let mut cells = Vec::new();
                let mut row = Vec::new();
                for (task, items) in &tasks {
                    let items = &items[..n.min(items.len())];
                    let cell = eval_cell(
                        &models, target, variant, task, items, temperature, false, true,
                    )?;
                    row.push(tables::cell(cell.mal, cell.wall_speedup));
                    cells.push(cell);
                }
                row.push(tables::cell(
                    tables::overall_mal(&cells),
                    tables::overall_wall_speedup(&cells),
                ));
                rows.push((variant.to_uppercase(), row));
                overall.push((variant.to_string(), cells));
            }
            let analog = &models.manifest.target(target)?.paper_analog;
            let t = tables::TableBlock {
                title: format!("{target} ({analog}), T={temperature}"),
                columns: vec![
                    "instruct".into(),
                    "wild".into(),
                    "gqa".into(),
                    "coco".into(),
                    "OVERALL".into(),
                ],
                rows,
            };
            report.line(t.render());
        }
    }
    report.finish();
    Ok(())
}
