//! Engine scheduling microbenchmark (section Perf, layer 3): run-to-
//! completion vs continuous batching on a mixed short/long workload.
//!
//! Uses the scripted backend (self-contained artifact dir under tmp), so it
//! runs anywhere -- no PJRT artifacts needed.  The workload is the serving
//! pattern continuous batching exists for: a burst of long batch decodes
//! arrives first, then short interactive requests.  Reported per policy:
//! p50/p99 client-perceived interactive latency (queue + service) and total
//! token throughput.  The step-scheduled p99 must not regress vs the
//! run-to-completion baseline -- it should collapse by orders of magnitude.
//!
//!     cargo bench --bench micro_engine

mod harness;

use std::time::Instant;

use harness::BenchReport;
use massv::coordinator::{
    DecodeMode, Engine, EngineConfig, Priority, Request, SchedPolicy,
};

const GEN_MAX: usize = 4096;
const N_LONG: usize = 8;
const LONG_MAX_NEW: usize = 3000;
const N_SHORT: usize = 24;
const SHORT_MAX_NEW: usize = 16;

fn image(phase: usize) -> Vec<f32> {
    massv::models::scripted::demo_image(phase)
}

struct PolicyResult {
    p50_ms: f64,
    p99_ms: f64,
    tokens: usize,
    wall_s: f64,
}

/// One run: N_LONG batch decodes arrive, then N_SHORT interactive requests.
/// Interactive latency is client-perceived (queue + service).
fn run_policy(dir: &str, policy: SchedPolicy) -> anyhow::Result<PolicyResult> {
    let engine = Engine::start(
        dir,
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers: 2,
            queue_capacity: 4096,
            policy,
            ..EngineConfig::default()
        },
    )?;
    let t0 = Instant::now();
    let long_rxs: Vec<_> = (0..N_LONG)
        .map(|i| {
            let mut req =
                Request::simple(engine.next_id(), &format!("w{} w{}", 5 + i, 6 + i), image(i));
            req.mode = DecodeMode::TargetOnly;
            req.gen.max_new = LONG_MAX_NEW;
            req.priority = Priority::Batch;
            engine.submit(req)
        })
        .collect();
    let short_rxs: Vec<_> = (0..N_SHORT)
        .map(|i| {
            let mut req =
                Request::simple(engine.next_id(), &format!("w{}", 20 + i), image(i + 3));
            req.gen.max_new = SHORT_MAX_NEW;
            req.priority = Priority::Interactive;
            engine.submit(req)
        })
        .collect();

    let mut tokens = 0usize;
    let interactive_ms = massv::metrics::Histogram::default();
    for rx in short_rxs {
        let r = rx.recv()?;
        assert!(r.error.is_none(), "{:?}", r.error);
        tokens += r.tokens.len();
        interactive_ms.record(r.queue_ms + r.latency_ms);
    }
    for rx in long_rxs {
        let r = rx.recv()?;
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), LONG_MAX_NEW, "batch decode must stay complete");
        tokens += r.tokens.len();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    engine.shutdown();
    Ok(PolicyResult {
        p50_ms: interactive_ms.percentile(50.0),
        p99_ms: interactive_ms.percentile(99.0),
        tokens,
        wall_s,
    })
}

fn main() -> anyhow::Result<()> {
    let mut report = BenchReport::new("micro_engine");
    let dir = massv::models::scripted::write_test_artifacts("micro_engine", GEN_MAX, false);
    report.line(format!(
        "workload: {N_LONG} batch x {LONG_MAX_NEW} tok (arrive first) + \
         {N_SHORT} interactive x {SHORT_MAX_NEW} tok, 2 workers"
    ));

    let mut results = Vec::new();
    for (name, policy) in [
        ("run-to-completion", SchedPolicy::RunToCompletion),
        ("continuous-batching", SchedPolicy::Continuous),
    ] {
        let r = run_policy(&dir, policy)?;
        report.line(format!(
            "{name:<20} interactive p50 {:>8.3} ms  p99 {:>8.3} ms | \
             {} tokens in {:.3}s -> {:>8.0} tok/s",
            r.p50_ms,
            r.p99_ms,
            r.tokens,
            r.wall_s,
            r.tokens as f64 / r.wall_s
        ));
        results.push((name, r));
    }

    let rtc = &results[0].1;
    let cont = &results[1].1;
    report.line(format!(
        "interactive p99 {:.3} ms -> {:.3} ms ({:.1}x); throughput {:.0} -> {:.0} tok/s",
        rtc.p99_ms,
        cont.p99_ms,
        if cont.p99_ms > 0.0 { rtc.p99_ms / cont.p99_ms } else { f64::INFINITY },
        rtc.tokens as f64 / rtc.wall_s,
        cont.tokens as f64 / cont.wall_s,
    ));
    let ok = cont.p99_ms <= rtc.p99_ms * 1.5 + 1.0;
    report.line(format!(
        "step-scheduled p99 must not regress vs run-to-completion: {}",
        if ok { "PASS" } else { "FAIL" }
    ));
    let (cont_p99, rtc_p99) = (cont.p99_ms, rtc.p99_ms);
    report.finish();
    std::fs::remove_dir_all(&dir).ok();
    assert!(ok, "continuous p99 {cont_p99:.3} ms regressed vs run-to-completion {rtc_p99:.3} ms");
    Ok(())
}
