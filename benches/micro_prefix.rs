//! Prefix-cache microbenchmark (section Perf, layer 3): warm vs cold
//! multimodal prefill on a repeated-image workload.
//!
//! Uses the scripted backend (self-contained artifact dir under tmp), so it
//! runs anywhere -- no PJRT artifacts needed.  The workload is the pattern
//! the prefix cache exists for (SpecVLM/ViSpec's vision-token redundancy
//! argument): multi-turn chat and eval sweeps keep re-sending the same few
//! images, so most prefills repeat a (target, drafter, image, prompt)
//! prefix the engine has already built.  Arrivals come from
//! `workload::repeated_image_schedule` (image-pool + reuse-probability
//! knobs).
//!
//! Reported: mean/p95 prefill latency split by cache outcome (cold = miss,
//! warm = prefix hit), the hit rate, encode dedup counts, and total token
//! throughput.  The run fails if warm prefill does not beat cold prefill.
//!
//! Besides the human-readable report, the run writes machine-readable
//! `target/paper/BENCH_prefix.json` -- CI smoke-runs this bench and
//! archives the JSON, seeding the perf trajectory for the cache.
//!
//!     cargo bench --bench micro_prefix [-- --quick]

mod harness;

use std::time::Instant;

use harness::BenchReport;
use massv::coordinator::{DecodeMode, Engine, EngineConfig, Request};
use massv::metrics::Histogram;
use massv::util::json::Json;
use massv::workload::{repeated_image_schedule, RepeatKnobs};

/// Long scripted streams make cold prefill cost visible (the stream build
/// is the scripted stand-in for the image-conditioned prefill pass).
const GEN_MAX: usize = 8192;
const IMAGE_POOL: usize = 6;
const REUSE_PROB: f64 = 0.6;

fn image(phase: usize) -> Vec<f32> {
    massv::models::scripted::demo_image(phase)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MASSV_BENCH_QUICK").ok().as_deref() == Some("1");
    let n_requests = if quick { 60 } else { 200 };

    let mut report = BenchReport::new("micro_prefix");
    let dir = massv::models::scripted::write_test_artifacts("micro_prefix", GEN_MAX, false);
    let engine = Engine::start(
        &dir,
        EngineConfig { workers: 2, queue_capacity: 4096, ..EngineConfig::default() },
    )?;

    let prompts = ["w5 w6 w7", "w8 w9", "w10 w11 w12", "w13"];
    let knobs = RepeatKnobs { image_pool: IMAGE_POOL, reuse_prob: REUSE_PROB };
    // rate is irrelevant (closed submission); only the item/image draws matter
    let schedule = repeated_image_schedule(n_requests, 1e6, prompts.len(), &knobs, 7);
    report.line(format!(
        "workload: {n_requests} requests, {} prompts x {IMAGE_POOL} images, \
         reuse_prob {REUSE_PROB}, gen_max {GEN_MAX}, 2 workers",
        prompts.len()
    ));

    let t0 = Instant::now();
    let rxs: Vec<_> = schedule
        .iter()
        .map(|a| {
            let mut req =
                Request::simple(engine.next_id(), prompts[a.item], image(a.image));
            req.mode = DecodeMode::Speculative {
                variant: "massv".into(),
                text_only_draft: false,
                adaptive: false,
            };
            req.gen.max_new = 8;
            engine.submit(req)
        })
        .collect();

    let cold_ms = Histogram::default();
    let warm_ms = Histogram::default();
    let mut tokens = 0usize;
    for rx in rxs {
        let r = rx.recv()?;
        assert!(r.error.is_none(), "{:?}", r.error);
        tokens += r.tokens.len();
        if r.cache_hit {
            warm_ms.record(r.prefill_ms);
        } else {
            cold_ms.record(r.prefill_ms);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let metrics = engine.scrape();
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();

    assert!(cold_ms.count() > 0 && warm_ms.count() > 0, "workload must mix cold and warm");
    let cold_mean = cold_ms.mean();
    let warm_mean = warm_ms.mean();
    let hit_rate = metrics["prefix_cache_hit_rate"];
    let throughput = tokens as f64 / wall_s;

    report.line(format!(
        "cold prefill (miss) n={:<4} mean {:>8.4} ms  p95 {:>8.4} ms",
        cold_ms.count(),
        cold_mean,
        cold_ms.percentile(95.0)
    ));
    report.line(format!(
        "warm prefill (hit)  n={:<4} mean {:>8.4} ms  p95 {:>8.4} ms",
        warm_ms.count(),
        warm_mean,
        warm_ms.percentile(95.0)
    ));
    report.line(format!(
        "hit rate {:.3} | encode fills {} hits {} | evictions {} | \
         {} tokens in {:.3}s -> {:>8.0} tok/s",
        hit_rate,
        metrics["vision_encode_fills"],
        metrics["vision_encode_hits"],
        metrics["prefix_cache_evictions"],
        tokens,
        wall_s,
        throughput
    ));
    let speedup = if warm_mean > 0.0 { cold_mean / warm_mean } else { f64::INFINITY };
    let ok = warm_mean < cold_mean;
    report.line(format!(
        "warm-prefill speedup {speedup:.1}x over cold: {}",
        if ok { "PASS" } else { "FAIL" }
    ));

    // machine-readable record for CI / the perf trajectory
    let json = Json::obj(vec![
        ("bench", Json::str("micro_prefix")),
        ("requests", Json::num(n_requests as f64)),
        ("image_pool", Json::num(IMAGE_POOL as f64)),
        ("reuse_prob", Json::num(REUSE_PROB)),
        ("gen_max", Json::num(GEN_MAX as f64)),
        ("cold_prefill_ms_mean", Json::num(cold_mean)),
        ("cold_prefill_ms_p95", Json::num(cold_ms.percentile(95.0))),
        ("warm_prefill_ms_mean", Json::num(warm_mean)),
        ("warm_prefill_ms_p95", Json::num(warm_ms.percentile(95.0))),
        ("warm_speedup", Json::num(speedup)),
        ("hit_rate", Json::num(hit_rate)),
        ("encode_fills", Json::num(metrics["vision_encode_fills"])),
        ("encode_hits", Json::num(metrics["vision_encode_hits"])),
        ("throughput_tps", Json::num(throughput)),
    ]);
    std::fs::create_dir_all("target/paper").ok();
    std::fs::write("target/paper/BENCH_prefix.json", format!("{}\n", json.to_string()))?;
    report.line("[json saved to target/paper/BENCH_prefix.json]");
    report.finish();
    assert!(
        ok,
        "warm prefill mean {warm_mean:.4} ms must beat cold prefill mean {cold_mean:.4} ms"
    );
    Ok(())
}
