//! Coordinator microbenchmarks (section Perf, layer 3): scheduler ops/sec
//! (no models), and end-to-end engine throughput scaling with the worker
//! pool over a real request mix.
//!
//!     cargo bench --bench micro_coordinator [-- --quick]

mod harness;

use std::time::Instant;

use harness::{artifacts_or_exit, items_per_cell, measure, summarize, BenchReport};
use massv::coordinator::{Engine, EngineConfig, Priority, Request, Scheduler};
use massv::workload;

fn main() -> anyhow::Result<()> {
    let mut report = BenchReport::new("micro_coordinator");

    // ---- pure scheduler throughput (no models) ---------------------------
    let sched: Scheduler<u64> = Scheduler::new(1 << 16);
    let us = measure(10, 200, || {
        for i in 0..1000u64 {
            let class = if i % 3 == 0 { Priority::Batch } else { Priority::Interactive };
            let _ = sched.submit(i, class);
        }
        for _ in 0..1000 {
            let _ = sched.try_pop();
        }
    });
    report.line(summarize("scheduler submit+pop x1000", &us));

    // ---- engine throughput vs worker count --------------------------------
    let dir = artifacts_or_exit("micro_coordinator");
    let n_req = items_per_cell() * 2;
    for workers in [1usize, 2, 4] {
        let engine = Engine::start(
            &dir,
            EngineConfig {
                default_target: "qwensim-L".into(),
                workers,
                queue_capacity: 1024,
                ..EngineConfig::default()
            },
        )?;
        let items = workload::load_task(
            &dir,
            "instruct",
            &engine.tokenizer,
            engine.models.manifest.p_max,
        )?;
        // warm the executable cache before timing
        let _ = engine.run(Request::simple(
            engine.next_id(),
            &items[0].prompt,
            items[0].image.clone(),
        ));
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_req)
            .map(|i| {
                let it = &items[i % items.len()];
                engine.submit(Request::simple(engine.next_id(), &it.prompt, it.image.clone()))
            })
            .collect();
        let mut tokens = 0usize;
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
            tokens += r.tokens.len();
        }
        let dt = t0.elapsed().as_secs_f64();
        report.line(format!(
            "engine workers={workers}: {n_req} reqs, {tokens} tokens in {dt:.2}s -> \
             {:.1} req/s, {:.0} tok/s, p95 latency {:.0} ms",
            n_req as f64 / dt,
            tokens as f64 / dt,
            engine.metrics.latency_ms.percentile(95.0)
        ));
        engine.shutdown();
    }
    report.finish();
    Ok(())
}
