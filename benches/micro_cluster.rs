//! Multi-replica scale-out microbenchmark (section Perf, layer 4):
//! hot-spot-image captioning traffic through the real TCP server at
//! 1 -> 2 -> 4 engine replicas, prefix-affinity routing vs blind random
//! routing.
//!
//! Uses the scripted backend (self-contained artifact dir under tmp), so
//! it runs anywhere -- no PJRT artifacts needed.  The workload is a
//! Zipf-skewed image popularity schedule (`workload::hotspot_image_schedule`)
//! replayed closed-loop by 8 client connections; arrival timestamps are
//! ignored so every topology is measured at saturation.  Reported per
//! cell: aggregate token throughput, mean request latency, cluster prefix
//! cache hit rate, and spill count.
//!
//! Two gates:
//!   * affinity vs random at 4 replicas: affinity's hit rate must beat
//!     random's (deterministic cache arithmetic -- each hot (image,
//!     prompt) prefix misses once cluster-wide under affinity but once
//!     per replica it lands on under random).  Hard assert in ALL modes.
//!   * scaling: 4-replica aggregate throughput must beat 1 replica.
//!     Hard assert on full runs only; `--quick` (the CI smoke, on 1-2
//!     shared cores where four replicas cannot physically out-run one)
//!     reports the ratio without gating, and the JSON still records it.
//!
//! Besides the human-readable report, the run writes machine-readable
//! `target/paper/BENCH_cluster.json` -- CI smoke-runs this bench and
//! archives the JSON, seeding the perf trajectory for replica scale-out.
//!
//!     cargo bench --bench micro_cluster [-- --quick]

mod harness;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use harness::BenchReport;
use massv::cluster::{ClusterConfig, ClusterEngine, RoutingPolicy};
use massv::coordinator::EngineConfig;
use massv::server::{Client, Server};
use massv::util::json::Json;
use massv::workload::{hotspot_image_schedule, HotSpotKnobs, MmArrival};

const GEN_MAX: usize = 4096;
const CLIENTS: usize = 8;
const IMAGE_POOL: usize = 12;
const PROMPTS: [&str; 4] = ["w5 w6 w7", "w8 w9", "w10 w11 w12 w13", "w14 w15"];

struct Cell {
    replicas: usize,
    routing: RoutingPolicy,
    tokens: usize,
    wall_s: f64,
    latency_ms: Vec<f64>,
    hit_rate: f64,
    replica_hit_rates: Vec<f64>,
    spills: f64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
}

/// One serving run: start a ClusterEngine behind the real TCP server,
/// replay the shared schedule closed-loop from CLIENTS connections, tear
/// everything down, and report what the cluster rollup saw.
fn run_cell(
    dir: &str,
    replicas: usize,
    routing: RoutingPolicy,
    schedule: &Arc<Vec<MmArrival>>,
    max_new: usize,
) -> Cell {
    let ce = Arc::new(
        ClusterEngine::start(
            dir,
            ClusterConfig {
                replicas,
                routing,
                // one worker per replica: replica count is the variable
                engine: EngineConfig {
                    workers: 1,
                    queue_capacity: 4096,
                    ..EngineConfig::default()
                },
                ..ClusterConfig::default()
            },
        )
        .expect("cluster start"),
    );
    let server = Server::new(ce.clone());
    let stop = server.stop_handle();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server_thread = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| addr_tx.send(a).unwrap()).unwrap();
    });
    let addr = addr_rx.recv().expect("server bind").to_string();

    let next = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let workers: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let addr = addr.clone();
            let next = next.clone();
            let schedule = schedule.clone();
            std::thread::spawn(move || -> (usize, Vec<f64>) {
                let mut client = Client::connect(&addr).expect("client connect");
                let mut tokens = 0usize;
                let mut lat_ms = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(a) = schedule.get(i) else { break };
                    let req = Json::obj(vec![
                        ("op", Json::str("generate")),
                        ("prompt", Json::str(PROMPTS[a.item % PROMPTS.len()])),
                        (
                            "image",
                            Json::arr_f32(&massv::models::scripted::demo_image(a.image)),
                        ),
                        ("seed", Json::num(i as f64)),
                        ("max_new", Json::num(max_new as f64)),
                    ]);
                    let r0 = Instant::now();
                    let resp = client.call(&req).expect("generate call");
                    lat_ms.push(r0.elapsed().as_secs_f64() * 1e3);
                    assert!(resp.get("error").is_none(), "{resp:?}");
                    tokens += resp.get("tokens").unwrap().to_i32_vec().unwrap().len();
                }
                (tokens, lat_ms)
            })
        })
        .collect();
    let mut tokens = 0usize;
    let mut latency_ms = Vec::new();
    for w in workers {
        let (t, l) = w.join().expect("client thread");
        tokens += t;
        latency_ms.extend(l);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    latency_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let m = ce.scrape();
    let cell = Cell {
        replicas,
        routing,
        tokens,
        wall_s,
        latency_ms,
        hit_rate: m["prefix_cache_hit_rate"],
        replica_hit_rates: (0..replicas)
            .map(|i| m[&format!("replica{i}_prefix_cache_hit_rate")])
            .collect(),
        spills: m["cluster_spills"],
    };
    stop.store(true, Ordering::Relaxed);
    server_thread.join().expect("server thread");
    Arc::try_unwrap(ce).unwrap_or_else(|_| panic!("cluster still shared")).shutdown();
    cell
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MASSV_BENCH_QUICK").ok().as_deref() == Some("1");
    let (max_new, n_requests) = if quick { (12, 160) } else { (32, 480) };

    let mut report = BenchReport::new("micro_cluster");
    let dir = massv::models::scripted::write_test_artifacts("micro_cluster", GEN_MAX, false);
    // Zipf-hot image pool: image 0 is the hot spot, plus a 30% chance each
    // arrival re-uses the previous image (bursty sessions).  One shared
    // schedule keeps every cell's traffic identical.
    let knobs = HotSpotKnobs { image_pool: IMAGE_POOL, zipf_s: 1.1, reuse_prob: 0.3 };
    let schedule =
        Arc::new(hotspot_image_schedule(n_requests, 1000.0, PROMPTS.len(), &knobs, 17));
    report.line(format!(
        "workload: {n_requests} hot-spot-image requests x {max_new} tokens, {CLIENTS} \
         closed-loop TCP clients; {IMAGE_POOL} images (zipf s=1.1, reuse 0.3), \
         {} prompts; 1 worker per replica",
        PROMPTS.len()
    ));

    let cells = [
        (1usize, RoutingPolicy::Affinity),
        (2, RoutingPolicy::Affinity),
        (4, RoutingPolicy::Affinity),
        (4, RoutingPolicy::Random),
    ];
    let mut results: Vec<Cell> = Vec::new();
    for &(replicas, routing) in &cells {
        let c = run_cell(&dir, replicas, routing, &schedule, max_new);
        report.line(format!(
            "replicas {replicas} {:<9}: {:>9.0} tok/s | latency p50 {:>7.2} ms p99 {:>7.2} ms \
             | hit rate {:.3} (per replica {:?}) | spills {}",
            format!("{:?}", c.routing).to_lowercase(),
            c.tokens as f64 / c.wall_s,
            percentile(&c.latency_ms, 0.50),
            percentile(&c.latency_ms, 0.99),
            c.hit_rate,
            c.replica_hit_rates.iter().map(|h| (h * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
            c.spills
        ));
        results.push(c);
    }

    let tps = |c: &Cell| c.tokens as f64 / c.wall_s;
    let r1 = &results[0];
    let r4_affinity = &results[2];
    let r4_random = &results[3];
    let scaling_4v1 = tps(r4_affinity) / tps(r1);
    let (hit_aff, hit_rand) = (r4_affinity.hit_rate, r4_random.hit_rate);

    report.line(format!(
        "affinity vs random hit rate at 4 replicas: {hit_aff:.3} vs {hit_rand:.3} -> {}",
        if hit_aff > hit_rand { "PASS" } else { "FAIL" }
    ));
    let scale_ok = quick || scaling_4v1 > 1.0;
    report.line(format!(
        "4-replica vs 1-replica aggregate throughput: {scaling_4v1:.2}x -> {}",
        if scaling_4v1 > 1.0 {
            "PASS"
        } else if quick {
            "ADVISORY (quick mode: smoke runners cannot parallelize 4 replicas)"
        } else {
            "FAIL"
        }
    ));

    let cell_json = |c: &Cell| {
        let mean = c.latency_ms.iter().sum::<f64>() / c.latency_ms.len() as f64;
        Json::obj(vec![
            ("replicas", Json::num(c.replicas as f64)),
            ("routing", Json::str(format!("{:?}", c.routing).to_lowercase())),
            ("tps", Json::num(tps(c))),
            ("tokens", Json::num(c.tokens as f64)),
            ("latency_ms_p50", Json::num(percentile(&c.latency_ms, 0.50))),
            ("latency_ms_p99", Json::num(percentile(&c.latency_ms, 0.99))),
            ("latency_ms_mean", Json::num(mean)),
            ("hit_rate", Json::num(c.hit_rate)),
            (
                "replica_hit_rates",
                Json::arr_f32(&c.replica_hit_rates.iter().map(|&h| h as f32).collect::<Vec<_>>()),
            ),
            ("spills", Json::num(c.spills)),
        ])
    };
    let json = Json::obj(vec![
        ("bench", Json::str("micro_cluster")),
        ("gen_max", Json::num(GEN_MAX as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("requests", Json::num(n_requests as f64)),
        ("clients", Json::num(CLIENTS as f64)),
        (
            "cells",
            Json::obj(vec![
                ("r1_affinity", cell_json(r1)),
                ("r2_affinity", cell_json(&results[1])),
                ("r4_affinity", cell_json(r4_affinity)),
                ("r4_random", cell_json(r4_random)),
            ]),
        ),
        ("scaling_4v1", Json::num(scaling_4v1)),
        ("affinity_hit_rate", Json::num(hit_aff)),
        ("random_hit_rate", Json::num(hit_rand)),
    ]);
    std::fs::create_dir_all("target/paper").ok();
    std::fs::write("target/paper/BENCH_cluster.json", format!("{}\n", json.to_string()))?;
    report.line("[json saved to target/paper/BENCH_cluster.json]");
    report.finish();
    std::fs::remove_dir_all(&dir).ok();

    // the cache arithmetic is load-independent: hard gate in every mode
    assert!(
        hit_aff > hit_rand,
        "affinity routing must beat random on cache hit rate: {hit_aff:.3} vs {hit_rand:.3}"
    );
    // wall-clock scaling needs real cores: hard gate on full runs only
    assert!(
        scale_ok,
        "4-replica throughput did not beat 1 replica: {scaling_4v1:.2}x"
    );
    Ok(())
}
