//! Scenario suite: every named workload scenario (`workload::scenario`)
//! replayed through the real serving stack -- TCP and HTTP/SSE fronts,
//! single-engine and 2-replica cluster topologies -- with per-scenario
//! serving metrics measured as scrape *windows* (`metrics::scrape_delta`)
//! so scenarios sharing one server don't bleed into each other's numbers.
//!
//! Uses the scripted backend (self-contained artifact dir under tmp), so
//! it runs anywhere -- no PJRT artifacts needed.  Traces are greedy
//! (temperature 0) and seeded, so the deterministic fields -- per-request
//! token streams, token totals, cache hit/miss counts -- are identical
//! across runs; latency fields (TTFT/TPOT percentiles, wall time) are
//! wall-clock and advisory.  A determinism gate replays the chat trace on
//! a second fresh engine and hard-asserts the deterministic fields match,
//! in every mode.
//!
//! Cells (front x replicas, scenarios windowed on a shared server):
//!   tcp  x1: chat_image_reuse, heavy_tail
//!   tcp  x2: multi_image_chat
//!   http x1: bursty_diurnal, mixed_tenants (bulk concurrency quota: real
//!            503 sheds, retried -- token totals stay deterministic)
//!   http x2: zipf_hotspot (prefix-affinity routing regime)
//!
//! Besides the human-readable report, the run writes machine-readable
//! `target/paper/BENCH_scenarios.json` -- CI smoke-runs this bench and
//! archives the JSON (`benches/baselines/BENCH_scenarios.json`).
//!
//!     cargo bench --bench scenario_suite [-- --quick]

mod harness;

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use harness::BenchReport;
use massv::cluster::{ClusterConfig, ClusterEngine};
use massv::coordinator::EngineConfig;
use massv::metrics::scrape_delta;
use massv::server::http::{GatewayConfig, HttpServer, Quota};
use massv::server::Server;
use massv::util::json::Json;
use massv::workload::scenario::replay::{percentile, replay, Front, ReplayOptions, ReplayReport};
use massv::workload::scenario::{by_name, ScenarioKnobs};

const GEN_MAX: usize = 4096;
const SEED_BASE: u64 = 0x5CE0;

struct Cell {
    name: &'static str,
    front: Front,
    replicas: usize,
    rep: ReplayReport,
    delta: HashMap<String, f64>,
}

fn front_str(f: Front) -> &'static str {
    match f {
        Front::Tcp => "tcp",
        Front::Http => "http",
    }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        workers: 2,
        // deep queue + generous cache + effectively-unbounded spill depth:
        // no engine-side sheds and no evictions, so cache hit counts are
        // pure trace arithmetic (deterministic) instead of timing artifacts
        queue_capacity: 4096,
        prefix_cache_bytes: 256 << 20,
        tenant_weights: vec![
            ("gold".to_string(), 4),
            ("silver".to_string(), 2),
            ("bulk".to_string(), 1),
        ],
        ..EngineConfig::default()
    }
}

fn cluster_cfg(replicas: usize) -> ClusterConfig {
    ClusterConfig {
        replicas,
        spill_depth: 1_000_000,
        engine: engine_cfg(),
        ..ClusterConfig::default()
    }
}

fn knobs_for(sidx: usize, requests: usize, rate: f64, max_new: usize) -> ScenarioKnobs {
    ScenarioKnobs {
        requests,
        rate,
        image_pool: 8,
        prompt_pool: 6,
        max_new,
        // disjoint image phases per scenario: traces sharing one server
        // must not warm each other's caches
        image_base: 1000 * (sidx + 1),
    }
}

fn opts_for(front: Front) -> ReplayOptions {
    ReplayOptions { front, streaming: true, time_scale: 1.0, retry_shed: true, shed_backoff_ms: 3 }
}

type Stopper = Box<dyn FnOnce() + Send>;

fn start_tcp(ce: Arc<ClusterEngine>) -> (String, Stopper) {
    let server = Server::new(ce);
    let stop = server.stop_handle();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| addr_tx.send(a).unwrap()).unwrap();
    });
    let addr = addr_rx.recv().expect("tcp bind").to_string();
    let stopper: Stopper = Box::new(move || {
        stop.store(true, Ordering::Relaxed);
        h.join().expect("tcp server thread");
    });
    (addr, stopper)
}

fn start_http(ce: Arc<ClusterEngine>, cfg: GatewayConfig) -> (String, Stopper) {
    let server = HttpServer::new(ce, cfg);
    let stop = server.stop_handle();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| addr_tx.send(a).unwrap()).unwrap();
    });
    let addr = addr_rx.recv().expect("http bind").to_string();
    let stopper: Stopper = Box::new(move || {
        stop.store(true, Ordering::Relaxed);
        h.join().expect("http server thread");
    });
    (addr, stopper)
}

fn cell_line(c: &Cell) -> String {
    let ttfts = c.rep.ttfts();
    let tpots = c.rep.tpots();
    format!(
        "{:<17} {:>4} x{}: {:>3} req {:>5} tok | ttft p50 {:>7.2} p99 {:>7.2} ms | \
         tpot p50 {:>5.2} ms | mal {:.2} | prefix hit {:.3} | encode hit {:.3} | \
         sheds {} | occ {:.2} | {:.2}s",
        c.name,
        front_str(c.front),
        c.replicas,
        c.rep.outcomes.len(),
        c.rep.total_tokens(),
        percentile(&ttfts, 50.0),
        percentile(&ttfts, 99.0),
        percentile(&tpots, 50.0),
        c.rep.mal_mean(),
        c.delta["prefix_cache_hit_rate"],
        c.delta["vision_encode_hit_rate"],
        c.rep.sheds(),
        c.delta["batch_occupancy_mean"],
        c.rep.wall_s,
    )
}

fn cell_json(c: &Cell) -> Json {
    let ttfts = c.rep.ttfts();
    let tpots = c.rep.tpots();
    let d = |k: &str| c.delta.get(k).copied().unwrap_or(0.0);
    Json::obj(vec![
        ("front", Json::str(front_str(c.front))),
        ("replicas", Json::num(c.replicas as f64)),
        ("requests", Json::num(c.rep.outcomes.len() as f64)),
        ("completed", Json::num(c.rep.completed() as f64)),
        ("tokens", Json::num(c.rep.total_tokens() as f64)),
        ("ttft_ms_p50", Json::num(percentile(&ttfts, 50.0))),
        ("ttft_ms_p99", Json::num(percentile(&ttfts, 99.0))),
        ("tpot_ms_p50", Json::num(percentile(&tpots, 50.0))),
        ("tpot_ms_p99", Json::num(percentile(&tpots, 99.0))),
        ("mal_mean", Json::num(c.rep.mal_mean())),
        ("prefix_cache_hits", Json::num(d("prefix_cache_hits"))),
        ("prefix_cache_hit_rate", Json::num(d("prefix_cache_hit_rate"))),
        ("vision_encode_hits", Json::num(d("vision_encode_hits"))),
        ("vision_encode_hit_rate", Json::num(d("vision_encode_hit_rate"))),
        ("shed_retries", Json::num(c.rep.sheds() as f64)),
        ("batch_occupancy_mean", Json::num(d("batch_occupancy_mean"))),
        ("wall_s", Json::num(c.rep.wall_s)),
    ])
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MASSV_BENCH_QUICK").ok().as_deref() == Some("1");
    let (requests, max_new, rate) = if quick { (48, 10, 64.0) } else { (160, 24, 48.0) };

    let mut report = BenchReport::new("scenario_suite");
    let dir = massv::models::scripted::write_test_artifacts("scenario_suite", GEN_MAX, false);
    report.line(format!(
        "scenario suite: {requests} requests/scenario, max_new {max_new}, rate {rate}/s, \
         seed base {SEED_BASE:#x}; 2 workers/replica, paced replay (time_scale 1.0)"
    ));

    let groups: [(Front, usize, &[&str]); 4] = [
        (Front::Tcp, 1, &["chat_image_reuse", "heavy_tail"]),
        (Front::Tcp, 2, &["multi_image_chat"]),
        (Front::Http, 1, &["bursty_diurnal", "mixed_tenants"]),
        (Front::Http, 2, &["zipf_hotspot"]),
    ];
    let mut cells: Vec<Cell> = Vec::new();
    let mut sidx = 0usize;
    for (front, replicas, names) in groups {
        let ce =
            Arc::new(ClusterEngine::start(&dir, cluster_cfg(replicas)).expect("cluster start"));
        // the bulk tenant runs under a real concurrency quota: its burst
        // phase sheds 503s at the gate, which the replay retries -- token
        // totals stay deterministic while shed counting gets exercised
        let gw = GatewayConfig {
            default_quota: Quota::default(),
            tenant_quotas: vec![(
                "bulk".to_string(),
                Quota { rps: 0.0, burst: 0.0, max_concurrent: 6 },
            )],
        };
        let (addr, stop) = match front {
            Front::Tcp => start_tcp(ce.clone()),
            Front::Http => start_http(ce.clone(), gw),
        };
        for &name in names {
            let knobs = knobs_for(sidx, requests, rate, max_new);
            let trace = by_name(name, &knobs, SEED_BASE + sidx as u64).expect("known scenario");
            let before = ce.scrape();
            let rep = replay(&addr, &trace, &opts_for(front)).expect("replay");
            let delta = scrape_delta(&before, &ce.scrape());
            assert_eq!(rep.completed(), requests, "{name}: every request must complete");
            assert_eq!(
                delta["requests_received"] as usize,
                requests,
                "{name}: the engine window must see exactly the trace (gate sheds excluded)"
            );
            let cell = Cell { name, front, replicas, rep, delta };
            report.line(cell_line(&cell));
            cells.push(cell);
            sidx += 1;
        }
        stop();
        Arc::try_unwrap(ce).unwrap_or_else(|_| panic!("cluster still shared")).shutdown();
    }

    // Determinism gate: replay the chat trace on a second fresh 1-replica
    // server; greedy traces + no-eviction caches make token streams and
    // hit counts pure arithmetic, so they must match exactly.
    let knobs = knobs_for(0, requests, rate, max_new);
    let trace = by_name("chat_image_reuse", &knobs, SEED_BASE).expect("known scenario");
    let ce = Arc::new(ClusterEngine::start(&dir, cluster_cfg(1)).expect("cluster start"));
    let (addr, stop) = start_tcp(ce.clone());
    let before = ce.scrape();
    let rep2 = replay(&addr, &trace, &opts_for(Front::Tcp)).expect("determinism replay");
    let delta2 = scrape_delta(&before, &ce.scrape());
    stop();
    Arc::try_unwrap(ce).unwrap_or_else(|_| panic!("cluster still shared")).shutdown();
    let chat = &cells[0];
    assert_eq!(
        rep2.token_streams(),
        chat.rep.token_streams(),
        "determinism: same trace, same per-request token streams"
    );
    assert_eq!(rep2.cache_hits(), chat.rep.cache_hits(), "determinism: client-observed hits");
    for k in
        ["tokens_generated", "prefix_cache_hits", "prefix_cache_misses", "vision_encode_hits"]
    {
        assert_eq!(delta2[k], chat.delta[k], "determinism: scrape window {k}");
    }
    report.line(format!(
        "determinism gate: chat_image_reuse re-run matches ({} tokens, {} cache hits) -> PASS",
        rep2.total_tokens(),
        rep2.cache_hits()
    ));

    // scenario-shape gates (deterministic cache arithmetic, all modes)
    let by = |n: &str| cells.iter().find(|c| c.name == n).expect("cell");
    let zipf = by("zipf_hotspot");
    assert!(
        by("chat_image_reuse").delta["vision_encode_hit_rate"] > 0.0,
        "chat follow-up turns must reuse vision encodes"
    );
    assert!(
        by("multi_image_chat").delta["vision_encode_hit_rate"] > 0.0,
        "multi-image revisits must reuse vision encodes"
    );
    assert!(
        zipf.delta["prefix_cache_hit_rate"] > 0.0,
        "zipf hot-spot traffic must repeat (image, prompt) prefixes"
    );
    assert_eq!(zipf.delta["cluster_spills"], 0.0, "unbounded spill depth: no spills");
    for c in &cells {
        assert!(c.rep.mal_mean() >= 1.0, "{}: accepted length below 1", c.name);
    }

    let json = Json::obj(vec![
        ("bench", Json::str("scenario_suite")),
        ("quick", Json::Bool(quick)),
        ("gen_max", Json::num(GEN_MAX as f64)),
        ("requests_per_scenario", Json::num(requests as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("rate", Json::num(rate)),
        ("seed_base", Json::num(SEED_BASE as f64)),
        ("scenarios", Json::obj(cells.iter().map(|c| (c.name, cell_json(c))).collect())),
        (
            "determinism",
            Json::obj(vec![
                ("token_streams_equal", Json::Bool(true)),
                ("cache_windows_equal", Json::Bool(true)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("target/paper").ok();
    std::fs::write("target/paper/BENCH_scenarios.json", format!("{}\n", json.to_string()))?;
    report.line("[json saved to target/paper/BENCH_scenarios.json]");
    report.finish();
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
