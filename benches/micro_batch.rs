//! Cross-request batching microbenchmark (section Perf, layer 3):
//! throughput vs concurrency, per-step dispatch (`max_batch = 1`) vs
//! ganged fused ticks (`max_batch = 16`).
//!
//! Uses the scripted backend (self-contained artifact dir under tmp), so
//! it runs anywhere -- no PJRT artifacts needed.  On stock batch-1
//! executables the fused tick's win is scheduler amortization: one
//! pop/requeue lock round-trip and one metrics update per tick instead of
//! per session step, which is exactly the overhead that grows with
//! concurrency.  Reported per concurrency level (1 / 4 / 16 sessions):
//! total token throughput under both dispatch modes, plus the ganged
//! engine's batch-occupancy stats.  The run also cross-checks determinism
//! (both modes must produce the same total token count -- streams are
//! seeded).  Gate at 16 concurrent sessions: the report marks PASS only
//! when batched >= sequential (best of N runs).  Full runs hard-fail
//! below a 0.95x noise guard; `--quick` (the CI smoke, ~96-token
//! workloads on shared runners) reports the ratio without hard-failing,
//! so wall-clock jitter cannot red an unrelated PR -- the JSON record
//! still captures any regression for the perf trajectory.
//!
//! Besides the human-readable report, the run writes machine-readable
//! `target/paper/BENCH_batch.json` -- CI smoke-runs this bench and
//! archives the JSON, seeding the perf trajectory for batched serving.
//!
//!     cargo bench --bench micro_batch [-- --quick]

mod harness;

use std::time::Instant;

use harness::BenchReport;
use massv::coordinator::{DecodeMode, Engine, EngineConfig, Request};
use massv::util::json::Json;

const GEN_MAX: usize = 4096;
const CONCURRENCY: [usize; 3] = [1, 4, 16];

struct Cell {
    tokens: usize,
    wall_s: f64,
    batch_ticks: f64,
    occupancy_mean: f64,
}

fn image(phase: usize) -> Vec<f32> {
    massv::models::scripted::demo_image(phase)
}

/// One engine run: `concurrency` speculative sessions submitted at once,
/// drained to completion.  Identical seeds across runs keep the workload
/// deterministic, so token counts must match between dispatch modes.
fn run_cell(dir: &str, concurrency: usize, max_batch: usize, max_new: usize) -> Cell {
    let engine = Engine::start(
        dir,
        EngineConfig {
            workers: 2,
            queue_capacity: 4096,
            max_batch,
            ..EngineConfig::default()
        },
    )
    .expect("engine start");
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..concurrency)
        .map(|i| {
            let mut req = Request::simple(
                engine.next_id(),
                &format!("w{} w{}", 5 + i % 4, 9 + i % 3),
                image(i % 4),
            );
            req.mode = DecodeMode::Speculative {
                variant: "massv".into(),
                text_only_draft: false,
                adaptive: false,
            };
            req.gen.max_new = max_new;
            req.gen.seed = i as u64;
            engine.submit(req)
        })
        .collect();
    let mut tokens = 0usize;
    for rx in rxs {
        let r = rx.recv().expect("engine reply");
        assert!(r.error.is_none(), "{:?}", r.error);
        tokens += r.tokens.len();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = engine.scrape();
    engine.shutdown();
    Cell {
        tokens,
        wall_s,
        batch_ticks: m["batch_ticks"],
        occupancy_mean: m["batch_occupancy_mean"],
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MASSV_BENCH_QUICK").ok().as_deref() == Some("1");
    let max_new = if quick { 96 } else { 512 };
    let repeats = if quick { 2 } else { 3 };

    let mut report = BenchReport::new("micro_batch");
    let dir = massv::models::scripted::write_test_artifacts("micro_batch", GEN_MAX, false);
    report.line(format!(
        "workload: N concurrent chain-speculative sessions x {max_new} tokens, 2 workers; \
         sequential (max_batch=1) vs batched (max_batch=16); best of {repeats}"
    ));

    let mut json_cells: Vec<(String, Json)> = Vec::new();
    let mut ratio_at_16 = 0.0f64;
    for &c in &CONCURRENCY {
        // best-of-N to damp scheduler/OS noise; determinism is asserted on
        // every run (same seeds -> same token totals in both modes)
        let mut seq: Option<Cell> = None;
        let mut bat: Option<Cell> = None;
        for _ in 0..repeats {
            let s = run_cell(&dir, c, 1, max_new);
            let b = run_cell(&dir, c, 16, max_new);
            assert_eq!(
                s.tokens, b.tokens,
                "dispatch mode must not change the (seeded) token streams"
            );
            assert_eq!(s.batch_ticks, 0.0, "max_batch=1 must never fuse ticks");
            let better = |best: &Option<Cell>, cand: &Cell| match best {
                None => true,
                Some(p) => cand.wall_s < p.wall_s,
            };
            if better(&seq, &s) {
                seq = Some(s);
            }
            if better(&bat, &b) {
                bat = Some(b);
            }
        }
        let (seq, bat) = (seq.unwrap(), bat.unwrap());
        let seq_tps = seq.tokens as f64 / seq.wall_s;
        let bat_tps = bat.tokens as f64 / bat.wall_s;
        let ratio = bat_tps / seq_tps;
        if c == 16 {
            ratio_at_16 = ratio;
        }
        report.line(format!(
            "concurrency {c:>2}: sequential {seq_tps:>9.0} tok/s | batched {bat_tps:>9.0} tok/s \
             ({ratio:>5.2}x) | fused ticks {} occ_mean {:.2}",
            bat.batch_ticks, bat.occupancy_mean
        ));
        json_cells.push((
            format!("c{c}"),
            Json::obj(vec![
                ("concurrency", Json::num(c as f64)),
                ("sequential_tps", Json::num(seq_tps)),
                ("batched_tps", Json::num(bat_tps)),
                ("speedup", Json::num(ratio)),
                ("batch_ticks", Json::num(bat.batch_ticks)),
                ("occupancy_mean", Json::num(bat.occupancy_mean)),
                ("tokens", Json::num(bat.tokens as f64)),
            ]),
        ));
    }

    let pass = ratio_at_16 >= 1.0;
    // hard gate only on full runs: quick smoke workloads are too short to
    // distinguish a real regression from shared-runner jitter
    let ok = quick || ratio_at_16 >= 0.95;
    report.line(format!(
        "batched >= sequential throughput at 16 concurrent sessions: \
         {ratio_at_16:.2}x -> {}",
        if pass {
            "PASS"
        } else if quick {
            "ADVISORY (quick mode: not gated)"
        } else if ok {
            "WITHIN-NOISE"
        } else {
            "FAIL"
        }
    ));

    let mut fields: Vec<(&str, Json)> = vec![
        ("bench", Json::str("micro_batch")),
        ("gen_max", Json::num(GEN_MAX as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("speedup_at_16", Json::num(ratio_at_16)),
    ];
    let cells: Vec<(&str, Json)> =
        json_cells.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    fields.push(("cells", Json::obj(cells)));
    let json = Json::obj(fields);
    std::fs::create_dir_all("target/paper").ok();
    std::fs::write("target/paper/BENCH_batch.json", format!("{}\n", json.to_string()))?;
    report.line("[json saved to target/paper/BENCH_batch.json]");
    report.finish();
    std::fs::remove_dir_all(&dir).ok();
    assert!(
        ok,
        "batched throughput regressed at 16 concurrent sessions: {ratio_at_16:.2}x"
    );
    Ok(())
}
