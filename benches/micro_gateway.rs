//! HTTP/SSE gateway microbenchmark (section Perf, serving layer): a
//! flooding tenant hammering `POST /v1/generate` in a closed loop while an
//! interactive tenant runs streaming requests through the same gateway --
//! with per-tenant admission control OFF (open door) and ON (flood tenant
//! rate+concurrency quota).
//!
//! Uses the scripted backend (self-contained artifact dir under tmp), so
//! it runs anywhere -- no PJRT artifacts needed.  Reported per cell:
//! flood admission/shed counts, interactive time-to-first-SSE-frame
//! (TTFT) p50/p99, and interactive end-to-end latency p50/p99.
//!
//! Gates (deterministic, load-independent -- hard in ALL modes):
//!   * every interactive request completes with HTTP 200 in both cells
//!     (the interactive tenant is never shed);
//!   * the open cell sheds nothing; the quota cell sheds the flood tenant
//!     (429s observed) and the gateway's `shed_429` counter agrees with
//!     the client-side count exactly.
//! The interactive TTFT improvement from shedding the flood at the front
//! door is reported as ADVISORY -- it is real on multi-core hosts but not
//! guaranteed on 1-2 shared CI cores.
//!
//! Besides the human-readable report, the run writes machine-readable
//! `target/paper/BENCH_gateway.json` -- CI smoke-runs this bench and
//! archives the JSON, seeding the perf trajectory for the gateway.
//!
//!     cargo bench --bench micro_gateway [-- --quick]

mod harness;

use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use harness::BenchReport;
use massv::coordinator::{Engine, EngineConfig};
use massv::server::http::{GatewayConfig, HttpClient, HttpServer, Quota};
use massv::util::json::Json;

const GEN_MAX: usize = 4096;
const FLOOD_CLIENTS: usize = 4;
const INTERACTIVE_CLIENTS: usize = 2;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
}

/// One streaming request over a raw socket, timing the first SSE frame.
/// Returns (ttft_ms, total_ms, data frames seen).  Panics on any non-200
/// status: the interactive tenant must never be shed.
fn streaming_request(addr: &str, tenant: &str, body: &str) -> (f64, f64, usize) {
    let t0 = Instant::now();
    let stream = std::net::TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let req = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nx-tenant: {tenant}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    writer.write_all(req.as_bytes()).unwrap();
    writer.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(
        line.starts_with("HTTP/1.1 200"),
        "interactive tenant was shed: {line:?}"
    );
    loop {
        let mut h = String::new();
        assert!(reader.read_line(&mut h).unwrap() > 0, "eof in headers");
        if h == "\r\n" || h == "\n" {
            break;
        }
    }
    let mut ttft_ms = None;
    let mut frames = 0usize;
    loop {
        let mut l = String::new();
        if reader.read_line(&mut l).unwrap() == 0 {
            break;
        }
        if let Some(data) = l.trim_end().strip_prefix("data: ") {
            if ttft_ms.is_none() {
                ttft_ms = Some(t0.elapsed().as_secs_f64() * 1e3);
            }
            if data == "[DONE]" {
                break;
            }
            frames += 1;
        }
    }
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(frames > 0, "stream carried no data frames");
    (ttft_ms.unwrap(), total_ms, frames)
}

struct Cell {
    name: &'static str,
    flood_attempted: usize,
    flood_ok: usize,
    flood_429: usize,
    flood_503: usize,
    gateway_429: u64,
    gateway_503: u64,
    ttft_ms: Vec<f64>,
    latency_ms: Vec<f64>,
    wall_s: f64,
}

/// One cell: an engine behind the HTTP gateway, FLOOD_CLIENTS tight-loop
/// non-streaming clients on tenant "flood", INTERACTIVE_CLIENTS streaming
/// clients on tenant "interactive" measuring TTFT.  The flood runs for the
/// whole interactive measurement window.
fn run_cell(
    dir: &str,
    name: &'static str,
    gateway: GatewayConfig,
    interactive_reqs: usize,
    interactive_max_new: usize,
    flood_max_new: usize,
) -> Cell {
    let engine = Arc::new(
        Engine::start(
            dir,
            EngineConfig { workers: 2, queue_capacity: 4096, ..EngineConfig::default() },
        )
        .expect("engine start"),
    );
    let server = HttpServer::new(engine.clone(), gateway);
    let stop = server.stop_handle();
    let counters = server.counters();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server_thread = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| addr_tx.send(a).unwrap()).unwrap();
    });
    let addr = addr_rx.recv().expect("gateway bind").to_string();

    let flood_body = Json::obj(vec![
        ("prompt", Json::str("w5 w6 w7")),
        ("image", Json::arr_f32(&massv::models::scripted::demo_image(1))),
        ("max_new", Json::num(flood_max_new as f64)),
        ("seed", Json::num(7.0)),
    ]);
    let done = Arc::new(AtomicBool::new(false));
    let flood_threads: Vec<_> = (0..FLOOD_CLIENTS)
        .map(|_| {
            let addr = addr.clone();
            let body = flood_body.clone();
            let done = done.clone();
            std::thread::spawn(move || -> (usize, usize, usize, usize) {
                let http = HttpClient::new(addr);
                let (mut attempted, mut ok, mut s429, mut s503) = (0, 0, 0, 0);
                while !done.load(Ordering::Relaxed) {
                    attempted += 1;
                    match http.generate(&body, Some("flood")).expect("flood request").0 {
                        200 => ok += 1,
                        429 => {
                            s429 += 1;
                            // back off a beat: a real client honors
                            // Retry-After; a busy-spin would just measure
                            // loopback syscall throughput
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        503 => {
                            s503 += 1;
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        s => panic!("unexpected flood status {s}"),
                    }
                }
                (attempted, ok, s429, s503)
            })
        })
        .collect();
    // let the flood build queue/batch pressure before measuring
    std::thread::sleep(std::time::Duration::from_millis(50));

    let t0 = Instant::now();
    let next = Arc::new(AtomicUsize::new(0));
    let interactive_threads: Vec<_> = (0..INTERACTIVE_CLIENTS)
        .map(|_| {
            let addr = addr.clone();
            let next = next.clone();
            std::thread::spawn(move || -> (Vec<f64>, Vec<f64>) {
                let mut ttft = Vec::new();
                let mut total = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= interactive_reqs {
                        break;
                    }
                    let body = Json::obj(vec![
                        ("prompt", Json::str("w8 w9 w10")),
                        (
                            "image",
                            Json::arr_f32(&massv::models::scripted::demo_image(i % 3)),
                        ),
                        ("max_new", Json::num(interactive_max_new as f64)),
                        ("seed", Json::num(i as f64)),
                        ("stream", Json::Bool(true)),
                    ])
                    .to_string();
                    let (t, l, _) = streaming_request(&addr, "interactive", &body);
                    ttft.push(t);
                    total.push(l);
                }
                (ttft, total)
            })
        })
        .collect();
    let mut ttft_ms = Vec::new();
    let mut latency_ms = Vec::new();
    for t in interactive_threads {
        let (a, b) = t.join().expect("interactive client");
        ttft_ms.extend(a);
        latency_ms.extend(b);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    done.store(true, Ordering::Relaxed);
    let (mut attempted, mut ok, mut s429, mut s503) = (0, 0, 0, 0);
    for t in flood_threads {
        let (a, o, r, b) = t.join().expect("flood client");
        attempted += a;
        ok += o;
        s429 += r;
        s503 += b;
    }
    ttft_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    latency_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let cell = Cell {
        name,
        flood_attempted: attempted,
        flood_ok: ok,
        flood_429: s429,
        flood_503: s503,
        gateway_429: counters.shed_429.get(),
        gateway_503: counters.shed_503.get(),
        ttft_ms,
        latency_ms,
        wall_s,
    };
    stop.store(true, Ordering::Relaxed);
    server_thread.join().expect("gateway thread");
    Arc::try_unwrap(engine).unwrap_or_else(|_| panic!("engine still shared")).shutdown();
    cell
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MASSV_BENCH_QUICK").ok().as_deref() == Some("1");
    let (interactive_reqs, interactive_max_new, flood_max_new) =
        if quick { (8, 12, 8) } else { (32, 32, 16) };
    let flood_quota = Quota { rps: 20.0, burst: 4.0, max_concurrent: 2 };

    let mut report = BenchReport::new("micro_gateway");
    let dir = massv::models::scripted::write_test_artifacts("micro_gateway", GEN_MAX, false);
    report.line(format!(
        "workload: {FLOOD_CLIENTS} closed-loop flood clients (tenant \"flood\", \
         {flood_max_new} tokens/req) vs {INTERACTIVE_CLIENTS} streaming clients \
         (tenant \"interactive\", {interactive_reqs} reqs x {interactive_max_new} tokens); \
         engine: 2 workers"
    ));
    report.line(format!(
        "quota cell flood limits: rps {} burst {} max_concurrent {}",
        flood_quota.rps, flood_quota.burst, flood_quota.max_concurrent
    ));

    let open = run_cell(
        &dir,
        "open",
        GatewayConfig::default(),
        interactive_reqs,
        interactive_max_new,
        flood_max_new,
    );
    let quota = run_cell(
        &dir,
        "quota",
        GatewayConfig {
            default_quota: Quota::default(),
            tenant_quotas: vec![("flood".to_string(), flood_quota)],
        },
        interactive_reqs,
        interactive_max_new,
        flood_max_new,
    );

    for c in [&open, &quota] {
        report.line(format!(
            "{:<6}: flood {:>5} attempted / {:>5} ok / {:>5} 429 / {:>3} 503 | \
             interactive TTFT p50 {:>7.2} ms p99 {:>7.2} ms | latency p50 {:>7.2} ms \
             p99 {:>7.2} ms | wall {:.2} s",
            c.name,
            c.flood_attempted,
            c.flood_ok,
            c.flood_429,
            c.flood_503,
            percentile(&c.ttft_ms, 0.50),
            percentile(&c.ttft_ms, 0.99),
            percentile(&c.latency_ms, 0.50),
            percentile(&c.latency_ms, 0.99),
            c.wall_s
        ));
    }

    let ttft_ratio = percentile(&open.ttft_ms, 0.99) / percentile(&quota.ttft_ms, 0.99);
    report.line(format!(
        "interactive TTFT p99, open vs quota: {:.2}x -> {}",
        ttft_ratio,
        if ttft_ratio > 1.0 {
            "PASS (shedding the flood improves interactive TTFT)"
        } else {
            "ADVISORY (no improvement measured; expected on 1-2 shared cores)"
        }
    ));
    report.line(format!(
        "shed accounting: open 429={} quota 429={} (gateway counter {}) -> {}",
        open.flood_429,
        quota.flood_429,
        quota.gateway_429,
        if open.flood_429 == 0
            && quota.flood_429 > 0
            && quota.gateway_429 as usize == quota.flood_429
        {
            "PASS"
        } else {
            "FAIL"
        }
    ));

    let cell_json = |c: &Cell| {
        Json::obj(vec![
            ("flood_attempted", Json::num(c.flood_attempted as f64)),
            ("flood_ok", Json::num(c.flood_ok as f64)),
            ("flood_shed_429", Json::num(c.flood_429 as f64)),
            ("flood_shed_503", Json::num(c.flood_503 as f64)),
            ("gateway_shed_429", Json::num(c.gateway_429 as f64)),
            ("gateway_shed_503", Json::num(c.gateway_503 as f64)),
            ("interactive_ttft_ms_p50", Json::num(percentile(&c.ttft_ms, 0.50))),
            ("interactive_ttft_ms_p99", Json::num(percentile(&c.ttft_ms, 0.99))),
            ("interactive_latency_ms_p50", Json::num(percentile(&c.latency_ms, 0.50))),
            ("interactive_latency_ms_p99", Json::num(percentile(&c.latency_ms, 0.99))),
            ("wall_s", Json::num(c.wall_s)),
        ])
    };
    let json = Json::obj(vec![
        ("bench", Json::str("micro_gateway")),
        ("gen_max", Json::num(GEN_MAX as f64)),
        ("interactive_requests", Json::num(interactive_reqs as f64)),
        ("interactive_max_new", Json::num(interactive_max_new as f64)),
        ("flood_max_new", Json::num(flood_max_new as f64)),
        ("flood_clients", Json::num(FLOOD_CLIENTS as f64)),
        ("interactive_clients", Json::num(INTERACTIVE_CLIENTS as f64)),
        (
            "flood_quota",
            Json::obj(vec![
                ("rps", Json::num(flood_quota.rps)),
                ("burst", Json::num(flood_quota.burst)),
                ("max_concurrent", Json::num(flood_quota.max_concurrent as f64)),
            ]),
        ),
        ("cells", Json::obj(vec![("open", cell_json(&open)), ("quota", cell_json(&quota))])),
        ("ttft_p99_open_over_quota", Json::num(ttft_ratio)),
    ]);
    std::fs::create_dir_all("target/paper").ok();
    std::fs::write("target/paper/BENCH_gateway.json", format!("{}\n", json.to_string()))?;
    report.line("[json saved to target/paper/BENCH_gateway.json]");
    report.finish();
    std::fs::remove_dir_all(&dir).ok();

    // deterministic gates: hard in every mode (TTFT ratio stays advisory)
    assert_eq!(open.flood_429, 0, "open cell must not rate-shed anyone");
    assert_eq!(open.gateway_429, 0);
    assert!(
        quota.flood_429 > 0,
        "quota cell must shed the flooding tenant: {} attempts, 0 shed",
        quota.flood_attempted
    );
    assert_eq!(
        quota.gateway_429 as usize, quota.flood_429,
        "gateway shed counter must agree with client-observed 429s"
    );
    Ok(())
}
