//! Figure 4: histogram of total variation distances (Eq. 6) between the
//! drafter's and target's next-token distributions along target-greedy
//! trajectories, MASSV vs MASSV-w/o-SDViT.  The paper's claim to
//! reproduce in shape: SDViT concentrates mass at low TVD (left-skewed);
//! without it the distribution is broad / heavy-tailed.
//!
//!     cargo bench --bench fig4_tvd [-- --quick]

mod harness;

use harness::{artifacts_or_exit, items_per_cell, BenchReport};
use massv::eval::tvd_histogram;
use massv::models::ModelSet;
use massv::stats;
use massv::tokenizer::Tokenizer;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_or_exit("fig4_tvd");
    let n = items_per_cell();
    let models = ModelSet::load(&dir)?;
    let tok = Tokenizer::load(&dir)?;
    let mut report = BenchReport::new("fig4_tvd");
    let target = "qwensim-L";

    // pool all four tasks like the paper's "multimodal SD benchmark"
    let mut items = Vec::new();
    for (_, task_items) in workload::load_all_tasks(&dir, &tok, models.manifest.p_max)? {
        items.extend(task_items.into_iter().take(n));
    }

    report.line(format!(
        "Figure 4 reproduction: TVD(drafter, target) histogram ({target}, {} contexts)\n",
        items.len()
    ));

    for variant in ["massv", "massv_wo_sdvit"] {
        let (hist, all) = tvd_histogram(&models, target, variant, &items, 20, 24)?;
        report.line(format!(
            "== {variant} ==  n={} mean TVD {:.3} median {:.3} | mass at TVD<0.2: {:.1}%",
            all.len(),
            stats::mean(&all),
            stats::median(&all),
            100.0 * hist.cdf(0.2)
        ));
        report.line(hist.render(50));
    }
    report.finish();
    Ok(())
}
