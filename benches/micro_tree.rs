//! Chain vs token-tree speculation microbenchmark: mean accepted length
//! and host-side decode throughput on scripted agreement profiles (no
//! artifacts needed -- this measures the decoder/acceptance machinery the
//! way micro_sampler measures the sampling primitives).
//!
//!     cargo bench --bench micro_tree

mod harness;

use harness::{measure, summarize, BenchReport};
use massv::spec::testing::{params, MockDraft, MockTarget, MockTreeDraft};
use massv::spec::tree::TreeConfig;
use massv::spec::{GenConfig, SpecDecoder};
use massv::util::rng::Rng;

/// A target stream plus a corrupted drafter line: every `period`-th
/// position (at `phase`) diverges from the target.  (Bench-local mock
/// profile -- deliberately simpler than `models::scripted::corrupt`, which
/// must additionally guarantee vocabulary-range invariants.)
fn corrupted(stream: &[i32], period: usize, phase: usize) -> Vec<i32> {
    stream
        .iter()
        .enumerate()
        .map(|(i, &t)| if i % period == phase % period { 90 + (i % 7) as i32 } else { t })
        .collect()
}

struct Profile {
    name: &'static str,
    /// chain drafter corruption period (larger = better aligned)
    period: usize,
}

fn main() {
    let mut report = BenchReport::new("micro_tree");
    report.line("chain vs tree speculation (scripted mocks, greedy, gamma=5)\n");

    let mut rng = Rng::seeded(7);
    let stream: Vec<i32> = (0..200).map(|_| 4 + rng.range(80) as i32).collect();
    let cfg = GenConfig::default();
    let tree_cfg = GenConfig {
        tree: Some(TreeConfig { branch: vec![2, 2, 1, 1, 1], max_nodes: 16 }),
        ..GenConfig::default()
    };

    for profile in [
        Profile { name: "high agreement (period 7)", period: 7 },
        Profile { name: "low agreement  (period 3)", period: 3 },
    ] {
        let primary = corrupted(&stream, profile.period, 1);
        let alt = corrupted(&stream, profile.period, 1 + profile.period / 2);

        let chain_dec = SpecDecoder::with_params(
            MockTarget::new(stream.clone()),
            MockDraft::new(primary.clone()),
            params(),
        );
        let tree_dec = SpecDecoder::with_params(
            MockTarget::new(stream.clone()),
            MockTreeDraft::new(vec![primary.clone(), alt.clone()]),
            params(),
        );

        let chain = chain_dec.generate(&[], &[0; 8], 3, &cfg).unwrap();
        let tree = tree_dec.generate_tree(&[], &[0; 8], 3, &tree_cfg).unwrap();
        assert_eq!(chain.tokens, tree.tokens, "both decoders are lossless");

        report.line(format!("== {} ==", profile.name));
        report.line(format!(
            "  chain: MAL {:.3} over {} verify calls",
            chain.mal(),
            chain.verify_calls
        ));
        report.line(format!(
            "  tree:  MAL {:.3} over {} verify calls  (mean path depth {:.2}, \
             branch utilization {:.2}, {} nodes drafted)",
            tree.mal(),
            tree.verify_calls,
            tree.mean_path_depth(),
            tree.branch_utilization(),
            tree.tree_nodes_drafted,
        ));
        report.line(format!(
            "  MAL improvement: {:+.1}%",
            100.0 * (tree.mal() / chain.mal().max(1e-9) - 1.0)
        ));

        // host-side throughput (the real win is fewer verify calls; this
        // bounds the extra tree bookkeeping cost)
        let n_tokens = chain.tokens.len() as f64;
        let us = measure(5, 200, || {
            let _ = chain_dec.generate(&[], &[0; 8], 3, &cfg).unwrap();
        });
        let med = median(&us);
        report.line(summarize("  chain generate (48 tok)", &us));
        report.line(format!("    -> {:.2} Mtok/s host-side", n_tokens / med));
        let us = measure(5, 200, || {
            let _ = tree_dec.generate_tree(&[], &[0; 8], 3, &tree_cfg).unwrap();
        });
        let med = median(&us);
        report.line(summarize("  tree generate (48 tok)", &us));
        report.line(format!("    -> {:.2} Mtok/s host-side\n", n_tokens / med));
    }
    report.finish();
}

fn median(us: &[f64]) -> f64 {
    let mut v = us.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}
