//! Figure 3: mean accepted lengths per task for qwensim-L at T=0,
//! baseline vs MASSV (the bar chart under Table 1's headline numbers).
//!
//!     cargo bench --bench fig3_mal [-- --quick]

mod harness;

use harness::{artifacts_or_exit, items_per_cell, BenchReport};
use massv::eval::{eval_cell, tables};
use massv::models::ModelSet;
use massv::tokenizer::Tokenizer;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_or_exit("fig3_mal");
    let n = items_per_cell();
    let models = ModelSet::load(&dir)?;
    let tok = Tokenizer::load(&dir)?;
    let mut report = BenchReport::new("fig3_mal");
    let tasks = workload::load_all_tasks(&dir, &tok, models.manifest.p_max)?;
    let target = "qwensim-L";

    report.line(format!(
        "Figure 3 reproduction: mean accepted length per task ({target}, T=0, {n} items/task)\n"
    ));

    let mut bars = Vec::new();
    let mut improvement = Vec::new();
    for variant in ["baseline", "massv"] {
        let mut cells = Vec::new();
        for (task, items) in &tasks {
            let items = &items[..n.min(items.len())];
            let c = eval_cell(&models, target, variant, task, items, 0.0, false, false)?;
            bars.push((format!("{variant}/{task}"), c.mal));
            cells.push(c);
        }
        let overall = tables::overall_mal(&cells);
        bars.push((format!("{variant}/OVERALL"), overall));
        improvement.push(overall);
    }
    report.line(tables::bar_chart("mean accepted length tau", &bars, "", 48));
    if improvement.len() == 2 && improvement[0] > 0.0 {
        report.line(format!(
            "overall improvement: {:.2} -> {:.2} ({:+.1}%)",
            improvement[0],
            improvement[1],
            100.0 * (improvement[1] / improvement[0] - 1.0)
        ));
    }
    report.finish();
    Ok(())
}
