//! Shared bench harness (criterion is not in the offline vendored set).
//!
//! Provides: warmup + repeated measurement with median/mean/stddev, a
//! common artifacts guard, and a tee-style writer that mirrors bench
//! output into `target/paper/<name>.txt` so every paper table/figure run
//! leaves a file behind.

#![allow(dead_code)]

use std::io::Write;
use std::time::Instant;

pub struct BenchReport {
    name: String,
    body: String,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), body: String::new() }
    }

    pub fn line(&mut self, s: impl AsRef<str>) {
        println!("{}", s.as_ref());
        self.body.push_str(s.as_ref());
        self.body.push('\n');
    }

    pub fn finish(self) {
        std::fs::create_dir_all("target/paper").ok();
        let path = format!("target/paper/{}.txt", self.name);
        if let Ok(mut f) = std::fs::File::create(&path) {
            let _ = f.write_all(self.body.as_bytes());
        }
        println!("\n[report saved to {path}]");
    }
}

/// Artifacts guard: paper benches need `make artifacts` to have run.
pub fn artifacts_or_exit(bench: &str) -> String {
    let dir = std::env::var("MASSV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        println!("SKIP {bench}: artifacts not found at {dir:?} (run `make artifacts`)");
        std::process::exit(0);
    }
    dir
}

/// Micro-benchmark: warmup then `n` timed iterations; returns per-iter
/// times in microseconds.
pub fn measure<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..n)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e6
        })
        .collect()
}

pub fn summarize(name: &str, micros: &[f64]) -> String {
    let mut v = micros.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = v[v.len() / 2];
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    let p95 = v[((v.len() as f64 * 0.95) as usize).min(v.len() - 1)];
    format!("{name:<42} n={:<4} median {med:>9.1} us  mean {mean:>9.1} us  p95 {p95:>9.1} us", v.len())
}

/// How many eval items to use per cell; benches accept `--quick` (or env
/// MASSV_BENCH_QUICK=1) for a fast smoke pass.
pub fn items_per_cell() -> usize {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MASSV_BENCH_QUICK").ok().as_deref() == Some("1");
    if quick {
        6
    } else {
        std::env::var("MASSV_BENCH_ITEMS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(24)
    }
}
