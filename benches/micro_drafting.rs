//! Vision-aware drafting microbenchmark (docs/drafting.md): drafter-side
//! vision token compression and acceptance-driven speculation calibration.
//!
//! Three parts, all on the scripted backend (self-contained artifact dir
//! under tmp; no PJRT artifacts needed):
//!
//! 1. **Drafter prefill cost vs ratio** -- times `DraftModel::
//!    prefill_encoded` directly at ratios 1x/4x/16x.  The scripted
//!    drafter's prefill walks `pooled_vision_digest` over
//!    `ceil(n_visual / ratio)` pooled tokens (the deterministic stand-in
//!    for running the vision prefix through the drafter layers), so the
//!    cost drops ~linearly with the ratio.  HARD GATE: median prefill at
//!    ratio 4x and 16x must beat full resolution.
//! 2. **MAL and losslessness vs ratio** -- engine-level chain decoding at
//!    each ratio: token streams must be bit-identical to full resolution
//!    (greedy acceptance emits the target argmax sequence no matter what
//!    the drafter proposed); MAL declines mildly (the scripted agreement
//!    period goes 7 -> 6 -> 5), the ViSpec/SpecVLM shape.
//! 3. **Calibration A/B** -- one mixed-class workload
//!    (`workload::repeated_image_schedule` class tags) run through a plain
//!    engine and a calibrated one.  Per class, two tree-mode probe
//!    requests land while the class is still inside the calibrator's
//!    warmup (so they are never steered), then a chain-mode body; once
//!    warmed, classes whose accepted-length EWMA saturates steer their
//!    chain admissions to tree drafting.  HARD GATE: pooled MAL over the
//!    chain body with calibration on must be >= off.  This is guaranteed, not
//!    aspirational: steering only ever upgrades a chain request to a tree
//!    whose primary root-to-leaf path IS the chain draft (depth ==
//!    gamma), so per iteration the accepted path is at least the chain
//!    accepted prefix, total tokens are unchanged (lossless), and verify
//!    calls can only shrink.  How much MAL improves (and how many classes
//!    steer) is workload-dependent and reported as advisory.
//!
//! Besides the human-readable report, the run writes machine-readable
//! `target/paper/BENCH_drafting.json` -- CI smoke-runs this bench and
//! archives the JSON.  A checked-in reference lives at
//! `benches/baselines/BENCH_drafting.json`.
//!
//!     cargo bench --bench micro_drafting [-- --quick]

mod harness;

use harness::{measure, summarize, BenchReport};
use massv::coordinator::{DecodeMode, Engine, EngineConfig, Request, Response};
use massv::models::ModelSet;
use massv::util::json::Json;
use massv::workload::{repeated_image_schedule, RepeatKnobs};

/// Small scripted streams: part 1 isolates the pooled-vision digest (the
/// drafter-prefill cost channel), so the common stream-build cost should
/// stay negligible next to it.
const GEN_MAX: usize = 64;
const RATIOS: [u32; 3] = [1, 4, 16];
const PROMPTS: [&str; 4] = ["w5 w6 w7", "w8 w9", "w10 w11 w12 w13", "w14 w15"];

fn image(phase: usize) -> Vec<f32> {
    massv::models::scripted::demo_image(phase)
}

fn chain_req(engine: &Engine, prompt: &str, phase: usize, task: &str) -> Request {
    let mut req = Request::simple(engine.next_id(), prompt, image(phase));
    req.task = task.into();
    req.gen.temperature = 0.0;
    req.gen.max_new = 40;
    req
}

fn median(us: &[f64]) -> f64 {
    let mut v = us.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

/// Pooled MAL over a request set: total emitted tokens per target verify
/// call (the paper's speedup quantity, aggregated the way eval does it).
fn pooled_mal(responses: &[Response]) -> f64 {
    let tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let verifies: usize = responses.iter().map(|r| r.verify_calls).sum();
    tokens as f64 / verifies.max(1) as f64
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MASSV_BENCH_QUICK").ok().as_deref() == Some("1");

    let mut report = BenchReport::new("micro_drafting");
    let dir = massv::models::scripted::write_test_artifacts("micro_drafting", GEN_MAX, false);

    // ------------------------------------------------ 1. prefill vs ratio
    let models = ModelSet::load(&dir)?;
    let target = models.target("qwensim-L")?;
    let drafter = models.drafter_for("qwensim-L", "massv")?;
    let n_visual = models.manifest.n_visual;
    let enc = target.encode_image(&image(0))?;
    let prompt_ids = [5i32, 6, 7, 8];
    let n_timed = if quick { 60 } else { 300 };

    report.line(format!(
        "drafter prefill vs vision ratio ({n_visual} vision tokens, scripted digest channel)"
    ));
    let mut prefill_us = [0.0f64; RATIOS.len()];
    for (i, &ratio) in RATIOS.iter().enumerate() {
        let us = measure(10, n_timed, || {
            let _ = drafter
                .prefill_encoded(Some(&enc), &prompt_ids, prompt_ids.len(), false, ratio)
                .unwrap();
        });
        prefill_us[i] = median(&us);
        report.line(summarize(&format!("  drafter prefill ratio {ratio:>2}x"), &us));
    }
    let speedup_4x = prefill_us[0] / prefill_us[1].max(1e-9);
    let speedup_16x = prefill_us[0] / prefill_us[2].max(1e-9);
    let prefill_ok = prefill_us[1] < prefill_us[0] && prefill_us[2] < prefill_us[0];
    report.line(format!(
        "  compressed prefill speedup: {speedup_4x:.2}x at 4x, {speedup_16x:.2}x at 16x -> {}",
        if prefill_ok { "PASS" } else { "FAIL" }
    ));

    // ---------------------------------------- 2. MAL + losslessness vs ratio
    let n_mal = if quick { 4 } else { 8 };
    let engine = Engine::start(&dir, EngineConfig { workers: 1, ..EngineConfig::default() })?;
    let mut mal_at = [0.0f64; RATIOS.len()];
    let mut reference: Vec<Vec<i32>> = Vec::new();
    for (ri, &ratio) in RATIOS.iter().enumerate() {
        let responses: Vec<Response> = (0..n_mal)
            .map(|i| {
                let mut req = chain_req(&engine, PROMPTS[i % PROMPTS.len()], i, "adhoc");
                req.draft_vision_ratio = Some(ratio);
                engine.run(req)
            })
            .collect();
        for r in &responses {
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        if ri == 0 {
            reference = responses.iter().map(|r| r.tokens.clone()).collect();
        } else {
            for (r, want) in responses.iter().zip(&reference) {
                assert_eq!(
                    &r.tokens, want,
                    "greedy tokens must be bit-identical across drafter vision ratios"
                );
            }
        }
        mal_at[ri] = pooled_mal(&responses);
        report.line(format!("  ratio {ratio:>2}x: MAL {:.3} (tokens identical)", mal_at[ri]));
    }
    engine.shutdown();

    // ------------------------------------------------- 3. calibration A/B
    let n_body = if quick { 18 } else { 48 };
    let knobs = RepeatKnobs { image_pool: 4, reuse_prob: 0.5 };
    let schedule = repeated_image_schedule(n_body, 1e6, PROMPTS.len(), &knobs, 11);
    let mut classes: Vec<&'static str> = Vec::new();
    for a in &schedule {
        if !classes.contains(&a.class) {
            classes.push(a.class);
        }
    }
    report.line(format!(
        "calibration A/B: {} tree probes + {n_body} chain requests over classes {classes:?}",
        2 * classes.len()
    ));

    let run_workload = |cfg: EngineConfig| -> anyhow::Result<(Vec<Response>, Engine)> {
        let engine = Engine::start(&dir, cfg)?;
        let mut out = Vec::new();
        // two tree probes per class: both land inside the calibrator's
        // warmup window (min_obs), so neither engine ever steers them --
        // they warm the per-class acceptance state, nothing else
        for class in &classes {
            for probe in 0..2 {
                let mut req = chain_req(&engine, PROMPTS[probe], probe, class);
                req.mode = DecodeMode::Tree {
                    variant: "massv".into(),
                    text_only_draft: false,
                    adaptive: false,
                };
                out.push(engine.run(req));
            }
        }
        // chain-mode body: the calibrated engine may steer warmed classes
        // back up to tree drafting
        for a in &schedule {
            out.push(engine.run(chain_req(&engine, PROMPTS[a.item], a.image, a.class)));
        }
        for r in &out {
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        Ok((out, engine))
    };

    let (off, plain) = run_workload(EngineConfig { workers: 1, ..EngineConfig::default() })?;
    plain.shutdown();
    let (on, calibrated) = run_workload(EngineConfig {
        workers: 1,
        calibration: true,
        ..EngineConfig::default()
    })?;
    let scrape = calibrated.scrape();
    calibrated.shutdown();

    for (a, b) in off.iter().zip(&on) {
        assert_eq!(a.tokens, b.tokens, "calibration must not change greedy tokens");
    }
    // gate on the chain-mode body only: every body request in the
    // calibrated engine is either untouched (identical deterministic
    // decode) or upgraded chain -> tree (same tokens, verify calls can
    // only shrink), so this inequality holds unconditionally.  Probes are
    // excluded -- their job is warming the calibrator, and once a class
    // warms mid-probe their shape is calibrator-state-dependent.
    let probe_count = 2 * classes.len();
    let mal_off = pooled_mal(&off[probe_count..]);
    let mal_on = pooled_mal(&on[probe_count..]);
    let steered = classes
        .iter()
        .filter(|c| scrape.get(&format!("calib_tree{{class=\"{c}\"}}")).copied() == Some(1.0))
        .count();
    let mal_ok = mal_on + 1e-9 >= mal_off;
    report.line(format!(
        "  MAL calibration off {mal_off:.3} | on {mal_on:.3} ({:+.1}%) | \
         {steered}/{} classes steered to tree -> {}",
        100.0 * (mal_on / mal_off.max(1e-9) - 1.0),
        classes.len(),
        if mal_ok { "PASS" } else { "FAIL" }
    ));

    std::fs::remove_dir_all(&dir).ok();

    // machine-readable record for CI / the perf trajectory
    let json = Json::obj(vec![
        ("bench", Json::str("micro_drafting")),
        ("gen_max", Json::num(GEN_MAX as f64)),
        ("n_visual", Json::num(n_visual as f64)),
        ("prefill_us_ratio1", Json::num(prefill_us[0])),
        ("prefill_us_ratio4", Json::num(prefill_us[1])),
        ("prefill_us_ratio16", Json::num(prefill_us[2])),
        ("prefill_speedup_4x", Json::num(speedup_4x)),
        ("prefill_speedup_16x", Json::num(speedup_16x)),
        ("mal_ratio1", Json::num(mal_at[0])),
        ("mal_ratio4", Json::num(mal_at[1])),
        ("mal_ratio16", Json::num(mal_at[2])),
        ("calib_requests", Json::num((n_body + 2 * classes.len()) as f64)),
        ("mal_calib_off", Json::num(mal_off)),
        ("mal_calib_on", Json::num(mal_on)),
        ("mal_gain", Json::num(mal_on / mal_off.max(1e-9))),
        ("classes_steered", Json::num(steered as f64)),
    ]);
    std::fs::create_dir_all("target/paper").ok();
    std::fs::write("target/paper/BENCH_drafting.json", format!("{}\n", json.to_string()))?;
    report.line("[json saved to target/paper/BENCH_drafting.json]");
    report.finish();

    assert!(
        prefill_ok,
        "compressed drafter prefill must beat full resolution: \
         {:.1} us at 1x vs {:.1} us at 4x / {:.1} us at 16x",
        prefill_us[0], prefill_us[1], prefill_us[2]
    );
    assert!(
        mal_ok,
        "calibration-on pooled MAL {mal_on:.3} regressed below calibration-off {mal_off:.3}"
    );
    Ok(())
}
