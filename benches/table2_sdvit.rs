//! Table 2: ablation on the effect of SDViT (Self-Distilled Visual
//! Instruction Tuning) on drafting performance, at temperature 0, on the
//! overall multimodal benchmark (all four tasks pooled).
//!
//! Rows per target: BASELINE (text-only drafting), MASSV w/o SDViT
//! (architectural adaptation + fixed-label fine-tune), full MASSV.
//! The paper's key observation to reproduce in *shape*: w/o SDViT lands
//! near (or below!) the baseline, full MASSV is clearly above it.
//!
//!     cargo bench --bench table2_sdvit [-- --quick]

mod harness;

use harness::{artifacts_or_exit, items_per_cell, BenchReport};
use massv::eval::{eval_cell, tables, CellResult};
use massv::models::ModelSet;
use massv::tokenizer::Tokenizer;
use massv::workload;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_or_exit("table2_sdvit");
    let n = items_per_cell();
    let models = ModelSet::load(&dir)?;
    let tok = Tokenizer::load(&dir)?;
    let mut report = BenchReport::new("table2_sdvit");
    let tasks = workload::load_all_tasks(&dir, &tok, models.manifest.p_max)?;

    report.line(format!(
        "Table 2 reproduction: SDViT ablation (overall benchmark, T=0, {n} items/task)\n"
    ));

    for target in ["qwensim-L", "gemsim-L"] {
        let mut rows = Vec::new();
        let mut baseline_mal = 0.0;
        for (label, variant) in [
            ("BASELINE", "baseline"),
            ("MASSV w/o SDViT", "massv_wo_sdvit"),
            ("MASSV", "massv"),
        ] {
            let mut cells: Vec<CellResult> = Vec::new();
            for (task, items) in &tasks {
                let items = &items[..n.min(items.len())];
                cells.push(eval_cell(&models, target, variant, task, items, 0.0, false, true)?);
            }
            let mal = tables::overall_mal(&cells);
            if variant == "baseline" {
                baseline_mal = mal;
            }
            // paper Table 2 reports speedup normalized to the BASELINE row
            let rel = if baseline_mal > 0.0 { mal / baseline_mal } else { 0.0 };
            let wall = tables::overall_wall_speedup(&cells);
            rows.push((
                label.to_string(),
                vec![format!("{mal:.2}"), format!("{rel:.2}x"), format!("{wall:.2}x")],
            ));
        }
        let analog = &models.manifest.target(target)?.paper_analog;
        let t = tables::TableBlock {
            title: format!("{target} ({analog})"),
            columns: vec!["tau".into(), "vs baseline".into(), "wall speedup".into()],
            rows,
        };
        report.line(t.render());
    }
    report.finish();
    Ok(())
}
