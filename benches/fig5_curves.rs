//! Figure 5: training loss curves of the two-phase MASSV pipeline
//! (phase 1 projector pretraining, phase 2 SDViT), rendered from the loss
//! log that python/compile/train.py wrote during `make artifacts`.
//!
//!     cargo bench --bench fig5_curves

mod harness;

use harness::{artifacts_or_exit, BenchReport};
use massv::util::json::parse;

fn sparkline(losses: &[(usize, f64)], width: usize, height: usize) -> String {
    if losses.is_empty() {
        return "(no data)".into();
    }
    let lo = losses.iter().map(|(_, l)| *l).fold(f64::INFINITY, f64::min);
    let hi = losses.iter().map(|(_, l)| *l).fold(0.0f64, f64::max);
    let span = (hi - lo).max(1e-9);
    // resample to `width` columns
    let cols: Vec<f64> = (0..width)
        .map(|c| {
            let idx = c * losses.len() / width;
            losses[idx].1
        })
        .collect();
    let mut rows = vec![String::new(); height];
    for v in cols {
        let level = (((v - lo) / span) * (height as f64 - 1.0)).round() as usize;
        for (r, row) in rows.iter_mut().enumerate() {
            let want = height - 1 - r; // top row = highest loss
            row.push(if level >= want { '*' } else { ' ' });
        }
    }
    let mut out = String::new();
    for (r, row) in rows.iter().enumerate() {
        let label = hi - span * r as f64 / (height as f64 - 1.0);
        out.push_str(&format!("{label:7.3} |{row}\n"));
    }
    out.push_str(&format!(
        "        +{} steps 0..{}\n",
        "-".repeat(width),
        losses.last().unwrap().0
    ));
    out
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_or_exit("fig5_curves");
    let mut report = BenchReport::new("fig5_curves");
    let text = std::fs::read_to_string(format!("{dir}/training_curves.json"))?;
    let v = parse(&text)?;
    let curves = v.req("curves")?.as_arr()?;

    report.line("Figure 5 reproduction: two-phase MASSV training loss curves\n");
    for phase in [
        "phase1_projector/qwensim-S",
        "phase2_sdvit/qwensim-S",
        "phase1_projector/gemsim-S",
        "phase2_sdvit/gemsim-S",
    ] {
        let pts: Vec<(usize, f64)> = curves
            .iter()
            .filter(|c| c.get("phase").and_then(|p| p.as_str().ok()) == Some(phase))
            .map(|c| {
                (
                    c.req("step").unwrap().as_usize().unwrap(),
                    c.req("loss").unwrap().as_f64().unwrap(),
                )
            })
            .collect();
        if pts.is_empty() {
            continue;
        }
        let first = pts.first().unwrap().1;
        let last = pts.last().unwrap().1;
        report.line(format!("== {phase} ==  loss {first:.3} -> {last:.3}"));
        report.line(sparkline(&pts, 64, 10));
    }
    report.finish();
    Ok(())
}
